import os
import sys

# Tests run on the single host device (multi-device cases force N host
# devices in their own subprocess, or are `distributed`-marked).
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
# Repo root too, so tests can import the `benchmarks` package (e.g. the
# stylized-facts smoke reuses benchmarks.emergent_dynamics.stylized_facts).
sys.path.insert(0, os.path.join(_HERE, ".."))
