"""Per-kernel shape/dtype sweeps against the pure-jnp oracle (bitwise)."""
import dataclasses

import numpy as np
import pytest

from repro.core.config import MarketConfig
from repro.core.step import initial_state
from repro.kernels import ref
from repro.kernels.kinetic_clearing import kinetic_clearing, pick_tile
from repro.kernels.naive_clearing import naive_clearing

FIELDS = ("bid", "ask", "last_price", "prev_mid", "price_path", "volume_path")


def _run_kernel(kernel_fn, cfg, mb, scan="cumsum"):
    import jax.numpy as jnp

    state = initial_state(cfg, jnp)
    out = kernel_fn(state.bid, state.ask, state.last_price, state.prev_mid,
                    cfg=cfg, mb=mb, scan=scan, interpret=True)
    return tuple(np.asarray(o) for o in out)


@pytest.mark.parametrize("M,A,L,S", [
    (4, 8, 16, 5),
    (8, 16, 32, 10),
    (16, 33, 64, 8),     # A not divisible by L
    (6, 128, 128, 6),    # A == L (paper's benchmark grid size)
    (2, 300, 256, 4),    # A > 2L, L > 128 (multi-lane-register grid)
    (32, 5, 8, 12),      # tiny L
])
@pytest.mark.parametrize("kernel", ["kinetic", "naive"])
def test_kernel_shape_sweep(M, A, L, S, kernel):
    cfg = MarketConfig(num_markets=M, num_agents=A, num_levels=L,
                       num_steps=S, seed=M * 1000 + A)
    oracle = ref.simulate_reference(cfg).to_numpy()
    fn = kinetic_clearing if kernel == "kinetic" else naive_clearing
    out = _run_kernel(fn, cfg, mb=pick_tile(M))
    for f, got in zip(FIELDS, out):
        want = np.asarray(getattr(oracle, f))
        assert got.shape == want.shape, f
        assert (got == want).all(), f"{kernel} {f} mismatch at {(M, A, L, S)}"


@pytest.mark.parametrize("mb", [1, 2, 4, 8])
def test_kinetic_tile_sweep(mb):
    cfg = MarketConfig(num_markets=8, num_agents=32, num_levels=32,
                       num_steps=6, seed=5)
    oracle = ref.simulate_reference(cfg).to_numpy()
    out = _run_kernel(kinetic_clearing, cfg, mb=mb)
    for f, got in zip(FIELDS, out):
        assert (got == np.asarray(getattr(oracle, f))).all()


@pytest.mark.parametrize("scan", ["cumsum", "hillis-steele"])
def test_kinetic_scan_modes(scan):
    cfg = MarketConfig(num_markets=8, num_agents=64, num_levels=128,
                       num_steps=8, seed=9)
    oracle = ref.simulate_reference(cfg).to_numpy()
    out = _run_kernel(kinetic_clearing, cfg, mb=4, scan=scan)
    for f, got in zip(FIELDS, out):
        assert (got == np.asarray(getattr(oracle, f))).all()


def test_population_mix_sweep():
    """Fig 7 sweep axis: vary momentum fraction, all engines still agree."""
    for amom in (0.0, 0.3, 0.7):
        cfg = MarketConfig(num_markets=4, num_agents=40, num_levels=32,
                           num_steps=10, alpha_momentum=amom, seed=3)
        oracle = ref.simulate_reference(cfg).to_numpy()
        out = _run_kernel(kinetic_clearing, cfg, mb=4)
        for f, got in zip(FIELDS, out):
            assert (got == np.asarray(getattr(oracle, f))).all()


def test_volume_bounded_by_mantissa():
    """Paper §IV-B: accumulated tick volume must stay far below 2^24 so f32
    integer adds stay exact (the basis of the bitwise-identity claim)."""
    cfg = MarketConfig(num_markets=4, num_agents=256, num_levels=32,
                       num_steps=50, seed=2)
    r = ref.simulate_reference(cfg).to_numpy()
    assert r.bid.max() < 2**24 / 1024
    assert r.ask.max() < 2**24 / 1024


def test_pick_tile():
    assert pick_tile(16384) == 8
    assert pick_tile(6) == 6
    assert pick_tile(7) == 7
    assert pick_tile(12, target=8) == 6


@pytest.mark.parametrize("kernel", ["pallas-kinetic", "pallas-naive"])
def test_padded_tile_prime_m_regression(kernel):
    """pick_tile pathology regression: M=63 must run the *same* padded tile
    shape as M=64 (MB=8, 8 grid cells) instead of degrading to MB=1, and the
    padded run must stay bitwise-identical to the unpadded oracle."""
    from repro.core.session import Engine

    eng = Engine(kernel)
    cfg63 = MarketConfig(num_markets=63, num_agents=16, num_levels=32,
                         num_steps=6, seed=11)
    cfg64 = dataclasses.replace(cfg63, num_markets=64)
    r63 = eng._runner(cfg63, 6)
    r64 = eng._runner(cfg64, 6)
    assert r63.tile.mb == 8 and r63.tile.m_padded == 64
    assert (r63.tile.mb, r63.tile.m_padded) == (r64.tile.mb,
                                                r64.tile.m_padded)

    oracle = ref.simulate_reference(cfg63).to_numpy()
    got = eng.open(cfg63).run_to_result(6).to_numpy()
    for f in ("bid", "ask", "last_price", "prev_mid", "price_path",
              "volume_path"):
        assert (np.asarray(getattr(got, f))
                == np.asarray(getattr(oracle, f))).all(), f
