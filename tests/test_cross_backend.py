"""Paper §IV-B: bitwise identity across engines + statistical equivalence.

Table II reproduced: every backend sharing the kinetic RNG stream produces
*bitwise-identical* books; backends with different RNG streams (SplitMix64,
PCG64 — the paper's CPU reference) agree statistically.
"""
import numpy as np
import pytest

from repro.core import engine
from repro.core.config import MarketConfig
from repro.kernels import ref

CFG = MarketConfig(num_markets=16, num_agents=64, num_levels=64,
                   num_steps=40, seed=11)

FIELDS = ("bid", "ask", "last_price", "prev_mid", "price_path", "volume_path")

BITWISE_BACKENDS = ["numpy", "jax-scan", "jax-per-step", "pallas-naive",
                    "pallas-kinetic"]


@pytest.fixture(scope="module")
def reference():
    return ref.simulate_reference(CFG).to_numpy()


@pytest.mark.parametrize("backend", BITWISE_BACKENDS)
def test_bitwise_identity(backend, reference):
    r = engine.simulate(CFG, backend=backend).to_numpy()
    for f in FIELDS:
        a, b = getattr(r, f), getattr(reference, f)
        assert a.dtype == b.dtype and a.shape == b.shape
        assert (a == b).all(), f"{backend}: field {f} differs"


def test_hillis_steele_mode_bitwise(reference):
    r = engine.simulate(CFG, backend="pallas-kinetic",
                        scan="hillis-steele").to_numpy()
    for f in FIELDS:
        assert (getattr(r, f) == getattr(reference, f)).all()


@pytest.mark.parametrize("backend", ["numpy-splitmix64", "numpy-pcg64"])
def test_statistical_equivalence(backend, reference):
    """Different RNG stream -> aggregate stats agree (paper: <0.1% at scale;
    looser here because the test config is far smaller than M=4096)."""
    from repro.core.result import SimResult

    r = engine.simulate(CFG, backend=backend).to_numpy()
    ref_r = SimResult(*reference)
    px_a, px_b = r.mean_clearing_price(), ref_r.mean_clearing_price()
    assert abs(px_a - px_b) / px_b < 0.05
    vol_a, vol_b = r.volume_per_market(), ref_r.volume_per_market()
    assert abs(vol_a - vol_b) / vol_b < 0.10


def test_tile_size_invariance():
    """Grid tiling must not change results (markets are independent)."""
    a = engine.simulate(CFG, backend="pallas-kinetic", mb=2).to_numpy()
    b = engine.simulate(CFG, backend="pallas-kinetic", mb=16).to_numpy()
    for f in FIELDS:
        assert (getattr(a, f) == getattr(b, f)).all()


def test_seed_reproducibility():
    a = engine.simulate(CFG, backend="pallas-kinetic").to_numpy()
    b = engine.simulate(CFG, backend="pallas-kinetic").to_numpy()
    for f in FIELDS:
        assert (getattr(a, f) == getattr(b, f)).all()


def test_seed_sensitivity():
    import dataclasses

    other = dataclasses.replace(CFG, seed=12)
    a = engine.simulate(CFG, backend="numpy").to_numpy()
    b = engine.simulate(other, backend="numpy").to_numpy()
    assert not (a.price_path == b.price_path).all()
