"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (required deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import make_batch
from repro.launch.steps import make_serve_step, make_train_step
from repro.models.model import Model

ARCHS = [a for a in ARCHITECTURES if a != "kineticsim"]


def _batch(cfg, B=2, T=32, step=0):
    shape = ShapeSpec("t", T, B, "train")
    return {k: jnp.asarray(v) for k, v in
            make_batch(cfg, shape, step).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves_or_runs(arch):
    """One optimizer step runs and changes parameters finitely."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    train_step, opt = make_train_step(cfg, optimizer_name="adamw")
    opt_state = opt.init(params)
    jstep = jax.jit(train_step)
    batch = _batch(cfg)
    p2, o2, s2, m = jstep(params, opt_state, jnp.int32(0), batch)
    assert np.isfinite(float(m["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    leaves = jax.tree_util.tree_leaves(p2)
    assert all(np.isfinite(np.asarray(l, dtype=np.float32)).all()
               for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, Smax = 2, 16
    cache = model.init_cache(B, Smax)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        cache, tok, pos = serve(params, cache,
                                {"tokens": tok, "pos": pos})
    assert tok.shape == (B, 1)
    assert (np.asarray(tok) >= 0).all()
    assert (np.asarray(tok) < cfg.vocab_size).all()  # padding never sampled


def test_prefill_matches_decode_qwen():
    """Prefill logits at the last prompt position == step-by-step decode."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    cfg = dataclasses.replace(cfg, remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, T = 2, 8
    tokens = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab_size, (B, T)),
        jnp.int32)
    logits_pref, _ = jax.jit(model.prefill)(params, {"tokens": tokens})

    cache = model.init_cache(B, T + 1)
    logits_dec = None
    for i in range(T):
        logits_dec, cache = jax.jit(model.decode_step)(
            params, cache, tokens[:, i:i + 1],
            jnp.full((B,), i, jnp.int32))
    # bf16 flash operands (EXPERIMENTS §Perf B2) put prefill's blockwise
    # softmax and decode's dense softmax a few bf16 ulps apart.
    np.testing.assert_allclose(np.asarray(logits_pref)[:, 0],
                               np.asarray(logits_dec)[:, 0],
                               rtol=5e-2, atol=5e-2)


def test_gemma2_local_global_mask_differs():
    """Sliding-window layers must attend differently from global layers."""
    cfg = get_config("gemma2-27b", smoke=True)
    assert cfg.layer_is_local(0) and not cfg.layer_is_local(1)


def test_ssm_long_context_state_is_constant_size():
    cfg = get_config("falcon-mamba-7b", smoke=True)
    model = Model(cfg)
    c_small = model.init_cache(1, 16)
    c_large = model.init_cache(1, 1 << 19)
    sz = lambda c: sum(np.prod(l.shape)
                       for l in jax.tree_util.tree_leaves(c))
    assert sz(c_small) == sz(c_large)  # O(1) decode state (long_500k basis)


def test_vocab_padding_masked():
    cfg = get_config("granite-3-8b", smoke=True)
    assert cfg.padded_vocab_size % 512 == 0
    cfg_full = get_config("granite-3-8b")
    assert cfg_full.padded_vocab_size % 16 == 0  # mesh-shardable
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits, _ = model.prefill(params, {"tokens": jnp.zeros((1, 4), jnp.int32)})
    pad = np.asarray(logits)[0, 0, cfg.vocab_size:]
    if pad.size:
        assert (pad < -1e29).all()
