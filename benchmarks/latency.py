"""Paper Fig 6: per-step latency distribution (11 trials, median + min-max).

Per-step latency is the end-to-end time of ONE simulation step, including
any dispatch overhead — the regime where the persistent engine's single
launch wins (paper: 22.1us vs 339-1704us).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FIXED_A, emit
from repro.core import engine
from repro.core.config import MarketConfig

TRIALS = 11


def _step_latency(backend: str, cfg: MarketConfig) -> tuple:
    """Median/min/max per-step latency via single-step simulations (the
    jit/interpret warmup is excluded by a warmup call)."""
    import dataclasses

    one = dataclasses.replace(cfg, num_steps=1)
    engine.simulate(one, backend=backend)  # warmup/compile
    times = []
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        engine.simulate(one, backend=backend)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), float(np.min(times)), float(np.max(times))


def run() -> list:
    cfg = MarketConfig(num_markets=256 if not _full() else 4096,
                       num_agents=FIXED_A)
    rows = []
    for b in ("numpy", "jax-per-step", "jax-scan", "pallas-naive",
              "pallas-kinetic"):
        med, lo, hi = _step_latency(b, cfg)
        rows.append((f"fig6/step_latency/{b}", med * 1e6,
                     f"min_us={lo * 1e6:.1f};max_us={hi * 1e6:.1f}"))
    return rows


def _full():
    from benchmarks.common import FULL

    return FULL


if __name__ == "__main__":
    emit(run())
