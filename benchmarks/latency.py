"""Paper Fig 6: per-step latency distribution (11 trials, median + min-max).

Per-step latency is the end-to-end time of ONE simulation step on a *warm*
session — the regime where the persistent engine's single launch wins
(paper: 22.1us vs 339-1704us). With the Session API this is finally the
real warm path: the engine compiles once, the books stay device-resident,
and each trial times exactly one ``Session.step()`` (its dedicated
single-step executable), with no re-init and no retrace.

    PYTHONPATH=src python -m benchmarks.latency \
        --backends numpy,jax-scan --json bench_latency.json
"""
from __future__ import annotations

import argparse
import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import FIXED_A, FULL, Row, _block, emit
from repro.core.config import MarketConfig
from repro.core.session import Engine

TRIALS = 11

DEFAULT_BACKENDS = ("numpy", "jax-per-step", "jax-scan", "pallas-naive",
                    "pallas-kinetic")


def _step_latency(backend: str,
                  cfg: MarketConfig) -> Tuple[float, float, float, int, int]:
    """Median/min/max warm per-step latency over ``TRIALS`` session steps,
    plus the cumulative trace count and the warm-section retrace delta."""
    eng = Engine(backend)
    sess = eng.open(cfg)
    _block(sess.step())  # warmup: compile the single-step executable
    warm_traces = eng.trace_count
    times = []
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        batch = sess.step()
        _block(batch)
        times.append(time.perf_counter() - t0)
    # A warm retrace is reported as data (traces_delta != 0) rather than a
    # crash, so the regression lands in BENCH_latency.json where it is
    # diffable across PRs — the CI retrace check fails the build on it.
    return (float(np.median(times)), float(np.min(times)),
            float(np.max(times)), eng.trace_count,
            eng.trace_count - warm_traces)


def run(backends=DEFAULT_BACKENDS) -> List[Row]:
    cfg = MarketConfig(num_markets=4096 if FULL else 256, num_agents=FIXED_A)
    rows = []
    for b in backends:
        med, lo, hi, traces, delta = _step_latency(b, cfg)
        # traces/traces_delta make compile regressions diffable across the
        # BENCH_*.json trajectory (delta must stay 0 on the warm path).
        rows.append((f"fig6/step_latency/{b}", med * 1e6,
                     f"min_us={lo * 1e6:.1f};max_us={hi * 1e6:.1f};"
                     f"traces={traces};traces_delta={delta}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS),
                    help="comma-separated backend list")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run([b for b in args.backends.split(",") if b])
    emit(rows, json_path=args.json, benchmark="latency")


if __name__ == "__main__":
    main()
