"""RL rollout throughput: host-loop ``Session.step`` vs in-graph ``rollout``.

The paper's persistent regime keeps state device-resident across steps; the
Session RL hook throws that away by crossing the host boundary per step.
This benchmark quantifies the gap a policy-in-the-loop trainer sees:

  * ``rl/session_step_loop/<backend>`` — a python loop of warm
    ``Session.step(actions)`` calls (one dispatch + host transfer per step);
  * ``rl/env_rollout/<backend>``       — the same policy, steps and markets
    as ONE ``repro.env.rollout`` (a single ``lax.scan`` executable on
    traceable backends; the NumPy references run the host-loop semantics).

Rows report µs/step with ``steps_per_s``/``events_per_s`` derived, plus the
``traces``/``traces_delta`` compile counters: ``traces`` is the engine's
cumulative ``Engine.trace_count`` after the timed section and
``traces_delta`` the retraces *during* it — a warm env rollout must never
retrace, and CI fails the build if ``traces_delta`` is nonzero (see
.github/workflows/ci.yml).

    PYTHONPATH=src python -m benchmarks.rl_rollout \
        --backends jax-scan,pallas-kinetic --json BENCH_rl.json
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import FIXED_A, FULL, Row, emit, time_call
from repro.core.config import MarketConfig
from repro.core.session import Engine, ExternalOrders
from repro.env import rollout

DEFAULT_BACKENDS = ("numpy", "jax-scan", "pallas-naive", "pallas-kinetic")


def _make_policy(num_levels: int):
    """Deterministic one-lot quote one tick inside the spread (traceable).

    The scripted maker from ``repro.train.policies`` — one stable function
    object per benchmark run, because the env's rollout executable is
    cached per (policy, n_steps) and a fresh closure per *call* would
    defeat the cache and retrace.
    """
    from repro.train.policies import make_market_maker

    return make_market_maker(num_levels)


def _bench_backend(backend: str, cfg: MarketConfig, n_steps: int,
                   trials: int, policy) -> List[Row]:
    rows: List[Row] = []
    events_per_step = cfg.num_markets * cfg.num_agents

    # --- host-loop Session.step (one dispatch + transfer per step) ---
    eng = Engine(backend)
    sess = eng.open(cfg)
    actions = ExternalOrders(side_buy=True, price=cfg.num_levels // 2,
                             qty=1.0)

    def step_loop():
        out = None
        for _ in range(n_steps):
            out = sess.step(actions)
        return out

    step_loop()  # warm the single-step executable
    warm = eng.trace_count
    t_loop, _ = time_call(step_loop, trials=trials, warmup=0)
    us = t_loop / n_steps * 1e6
    rows.append((f"rl/session_step_loop/{backend}", us,
                 f"steps_per_s={n_steps / t_loop:.1f};"
                 f"events_per_s={events_per_step * n_steps / t_loop:.3e};"
                 f"traces={eng.trace_count};"
                 f"traces_delta={eng.trace_count - warm}"))

    # --- in-graph rollout (one executable for the whole trajectory) ---
    env_eng = Engine(backend)
    env = env_eng.env(cfg, auto_reset=False)

    def run_rollout():
        state, traj = rollout(env, policy, n_steps)
        return traj.reward

    run_rollout()  # warm the rollout executable outside the timed section
    warm = env_eng.trace_count
    t_roll, reward = time_call(run_rollout, trials=trials, warmup=0)
    assert reward.shape[0] == n_steps
    us = t_roll / n_steps * 1e6
    rows.append((f"rl/env_rollout/{backend}", us,
                 f"steps_per_s={n_steps / t_roll:.1f};"
                 f"events_per_s={events_per_step * n_steps / t_roll:.3e};"
                 f"speedup_vs_step_loop={t_loop / t_roll:.2f};"
                 f"traces={env_eng.trace_count};"
                 f"traces_delta={env_eng.trace_count - warm}"))
    return rows


def run(backends=DEFAULT_BACKENDS, markets: int = None, agents: int = None,
        steps: int = None, trials: int = 3) -> List[Row]:
    M = markets or (4096 if FULL else 64)
    A = agents or FIXED_A
    S = steps or (500 if FULL else 64)
    cfg = MarketConfig(num_markets=M, num_agents=A, num_steps=max(S, 2),
                       seed=11)
    policy = _make_policy(cfg.num_levels)
    rows: List[Row] = []
    for b in backends:
        rows.extend(_bench_backend(b, cfg, S, trials, policy))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS),
                    help="comma-separated backend list")
    ap.add_argument("--markets", type=int, default=None)
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None,
                    help="rollout length (steps per trajectory)")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run([b for b in args.backends.split(",") if b],
               markets=args.markets, agents=args.agents, steps=args.steps,
               trials=args.trials)
    emit(rows, json_path=args.json, benchmark="rl_rollout")


if __name__ == "__main__":
    main()
