"""Scenario realism gate: the pinned stylized-facts battery as a CI artifact.

Runs :func:`repro.scenario.validate.validate_spec` on every pinned mixture
(high-vol momentum + the whale / HFT / informed archetype mixtures) over
**one warm engine** — the pinned mixtures share a static shape, so after
the first compile every further mixture must reuse the executable. The
artifact rows carry the kurtosis / volume-volatility / ACF numbers plus a
``traces_delta`` row; the process exits nonzero if any mixture fails the
gate **or** a warm run retraced.

    PYTHONPATH=src python -m benchmarks.scenario_realism \
        [--backend jax-scan] [--steps 500] [--stats-check]
        [--json BENCH_scenario_realism.json]
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Tuple

from benchmarks.common import Row, emit, time_call
from repro.core.session import Engine
from repro.scenario.validate import PINNED_MIXTURES, validate_spec


def run(backend: str = "jax-scan", steps: int = None,
        stats_check: bool = False) -> Tuple[List[Row], bool]:
    """Returns (artifact rows, gate_ok)."""
    from repro.scenario.validate import PINNED_STEPS

    steps = PINNED_STEPS if steps is None else steps
    eng = Engine(backend)
    names = list(PINNED_MIXTURES)
    # Warm the shared executable on the first mixture; every subsequent
    # mixture (and the timed re-runs) must stay on the warm path.
    validate_spec(PINNED_MIXTURES[names[0]](steps), backend=backend,
                  eng=eng)
    warm = eng.trace_count

    rows: List[Row] = []
    all_passed = True
    for name in names:
        cfg = PINNED_MIXTURES[name](steps)
        t, rep = time_call(validate_spec, cfg, backend=backend,
                           scenario=name, stats_check=stats_check, eng=eng,
                           trials=1, warmup=0)
        all_passed &= rep.passed
        f = rep.facts
        rows.append((
            f"realism/{name}", t * 1e6,
            f"passed={int(rep.passed)};"
            f"kurtosis={f['kurtosis']:.4f};"
            f"vv_corr={f['volume_volatility_corr']:.4f};"
            f"acf_abs_lag1={f['acf_abs_lag1']:.4f};"
            f"acf_abs_lag10={f['acf_abs_lag10']:.4f};"
            f"volatility={f['volatility']:.4f};"
            f"failures={','.join(c.name for c in rep.failures) or 'none'}"))
    traces_delta = eng.trace_count - warm
    rows.append((
        "realism/warm_engine", 0.0,
        f"backend={backend};mixtures={len(names)};compiles={warm};"
        f"traces_delta={traces_delta}"))
    return rows, all_passed and traces_delta == 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jax-scan")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the pinned horizon (CI uses the default)")
    ap.add_argument("--stats-check", action="store_true",
                    help="cross-validate path moments vs in-kernel stats")
    ap.add_argument("--json", default=None,
                    metavar="BENCH_scenario_realism.json")
    ns = ap.parse_args()
    rows, ok = run(backend=ns.backend, steps=ns.steps,
                   stats_check=ns.stats_check)
    emit(rows, json_path=ns.json, benchmark="scenario_realism")
    if not ok:
        print("realism gate FAILED (stylized-facts check or warm retrace)",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
