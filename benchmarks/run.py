"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``name,us_per_call,derived`` CSV rows (+ section headers on stderr).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit

SECTIONS = [
    ("work_depth", "benchmarks.work_depth"),            # paper §III-F
    ("correctness", "benchmarks.correctness"),          # Table II + §IV-C
    ("fixed_workload", "benchmarks.fixed_workload"),    # Table IV
    ("throughput_sweep", "benchmarks.throughput_sweep"),# Table III / Fig 3-4
    ("latency", "benchmarks.latency"),                  # Fig 6
    ("memory_footprint", "benchmarks.memory_footprint"),# Table V / Fig 5
    ("emergent_dynamics", "benchmarks.emergent_dynamics"),  # Fig 7
    ("scenario_sweep", "benchmarks.scenario_sweep"),    # scenario engine
    ("roofline", "benchmarks.roofline_report"),         # EXPERIMENTS §Roofline
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[s for s, _ in SECTIONS] + [None])
    args = ap.parse_args()
    import importlib

    failures = 0
    for name, module in SECTIONS:
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", file=sys.stderr, flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            emit(mod.run())
        except Exception as e:  # report and continue: partial results beat none
            failures += 1
            print(f"{name},0.0,BENCH_ERROR:{type(e).__name__}:{e}",
                  flush=True)
        print(f"# === {name} done in {time.time() - t0:.1f}s ===",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
