"""Analytical TPU roofline of the KineticSim clearing kernel
(EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import math

from benchmarks.common import emit
from repro.launch.mesh import HW


def kinetic_kernel_roofline(M=16384, A=256, L=128, S=500, mb=8) -> dict:
    """Analytical TPU roofline for the persistent clearing kernel.

    Per market-step: agent math ~60 VPU flops/agent; one-hot binning
    2*A*L MXU flops x2 sides; scans 2*L*log2(L); clearing ~6*L.
    HBM traffic: books in+out once per simulation + price/volume paths.
    """
    flops_step = (60 * A + 2 * 2 * A * L + 2 * L * math.log2(L) + 8 * L)
    total_flops = M * S * flops_step
    hbm_bytes = 2 * 2 * M * L * 4 + 2 * M * S * 4 + 4 * M * 4
    t_comp = total_flops / HW["peak_flops_bf16"]  # MXU-dominated binning
    t_mem = hbm_bytes / HW["hbm_bw"]
    intensity = total_flops / hbm_bytes
    return {
        "flops": total_flops, "hbm_bytes": hbm_bytes,
        "compute_s": t_comp, "memory_s": t_mem,
        "arithmetic_intensity": intensity,
        "bound": "compute" if t_comp > t_mem else "memory",
        "events_per_s_bound": M * A * S / max(t_comp, t_mem),
    }


def run() -> list:
    rows = []
    k = kinetic_kernel_roofline()
    rows.append(("roofline/kinetic_kernel", 0.0,
                 f"intensity={k['arithmetic_intensity']:.0f}flops_per_byte;"
                 f"bound={k['bound']};"
                 f"events_per_s_bound={k['events_per_s_bound']:.3g}"))
    naive_bytes = 2 * 2 * 16384 * 128 * 4 * 500  # Theta(S*M*L)
    rows.append(("roofline/naive_kernel_traffic", 0.0,
                 f"bytes={naive_bytes:.3g};"
                 f"memory_s={naive_bytes / HW['hbm_bw']:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
