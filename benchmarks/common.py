"""Shared benchmark utilities: timing, CSV rows, scale configuration.

This container is CPU-only, so the paper's absolute GPU numbers cannot be
reproduced; every benchmark reproduces the paper's *structure* (same sweeps,
same metrics, same baseline set) at CPU-tractable scale. ``FULL_SCALE=1``
in the environment switches to the paper's exact configuration for runs on
real hardware.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

FULL = bool(int(os.environ.get("FULL_SCALE", "0")))

# (paper value, CPU-reduced value)
MARKET_SWEEP = [64, 256, 1024, 4096, 16384] if FULL else [16, 64, 256]
AGENT_SWEEP = [16, 64, 256, 1024] if FULL else [16, 64, 256]
FIXED_M = 8192 if FULL else 128
FIXED_A = 256 if FULL else 128
STEPS = 500 if FULL else 50
LEVELS = 128

Row = Tuple[str, float, str]


def time_call(fn: Callable, *args, trials: int = 5, warmup: int = 1,
              **kwargs) -> Tuple[float, object]:
    """Median wall-time (seconds) over ``trials``; returns (t, last_result)."""
    result = None
    for _ in range(warmup):
        result = fn(*args, **kwargs)
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        _block(result)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), result


def _block(x):
    try:
        import jax

        jax.block_until_ready(
            [l for l in jax.tree_util.tree_leaves(x)
             if hasattr(l, "block_until_ready")])
    except Exception:
        pass


def events_per_s(cfg, seconds: float) -> float:
    return cfg.events() / seconds if seconds > 0 else float("nan")


def emit(rows: List[Row], json_path: Optional[str] = None,
         benchmark: Optional[str] = None) -> None:
    """Print CSV rows; optionally also write a machine-readable JSON artifact
    (the seed of the ``BENCH_*.json`` trajectory uploaded by CI)."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
        sys.stdout.flush()
    if json_path:
        write_json(rows, json_path, benchmark or "benchmark")


def write_json(rows: List[Row], path: str, benchmark: str) -> None:
    payload = {
        "benchmark": benchmark,
        "full_scale": FULL,
        "rows": [
            {"name": name, "us": us,
             "derived": dict(kv.split("=", 1) for kv in derived.split(";")
                             if "=" in kv)}
            for name, us, derived in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
