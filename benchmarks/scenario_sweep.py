"""Scenario-sweep throughput: one `EnsembleSpec` launch vs a per-config loop.

The seed benchmark ran every (scenario, mixture) configuration as its own
engine run — N compiles, N launch streams. The ensemble-first API folds the
whole sweep into one heterogeneous `EnsembleSpec`: every scenario parameter
is a per-market device operand, so the entire mixture costs **one compile**
and **one kernel launch per chunk**. This benchmark measures both paths on
the same workload and reports compiles, launches, wall time, and events/s —
the regression CI checks that the ensemble path's compile count stays at 1.

    PYTHONPATH=src python -m benchmarks.scenario_sweep \
        [--backends numpy,jax-scan,pallas-kinetic] [--markets 16]
        [--agents 64] [--steps 50] [--trials 3] [--json BENCH_scenario.json]
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from benchmarks.common import (FIXED_A, FIXED_M, STEPS, Row, emit,
                               time_call, write_json)
from repro.core.config import scenario_config, scenario_names
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.scenario import CouplingSpec

DEFAULT_BACKENDS = ["numpy", "jax-scan", "pallas-kinetic"]

MIXTURES = {
    "paper": dict(alpha_maker=0.15, alpha_momentum=0.15),
    "hetero4": dict(alpha_maker=0.10, alpha_momentum=0.20,
                    alpha_fundamentalist=0.25),
}


def _sweep_configs(markets: int, agents: int, steps: int):
    """The (scenario × mixture) grid, one config per cell."""
    return [
        scenario_config(scenario, num_markets=markets, num_agents=agents,
                        num_steps=steps, **mix)
        for scenario in scenario_names()
        for mix in MIXTURES.values()
    ]


def run(backends: Optional[List[str]] = None, markets: Optional[int] = None,
        agents: Optional[int] = None, steps: Optional[int] = None,
        trials: int = 3) -> List[Row]:
    backends = backends or DEFAULT_BACKENDS
    markets = FIXED_M // 4 if markets is None else markets
    agents = FIXED_A if agents is None else agents
    steps = STEPS if steps is None else steps
    cfgs = _sweep_configs(markets, agents, steps)
    spec = EnsembleSpec.from_scenarios(cfgs)
    n_cfg = len(cfgs)
    chunk = min(64, steps)
    launches_per_run = -(-steps // chunk)
    total_events = spec.events()

    rows: List[Row] = []
    for b in backends:
        # --- per-config loop: the pre-ensemble regime -------------------
        loop_eng = Engine(b, chunk_size=chunk)

        # Closures return the device results so time_call's block() actually
        # synchronizes — otherwise async dispatch would be all we time.
        def run_loop():
            out = []
            for cfg in cfgs:
                with loop_eng.open(cfg) as sess:
                    out.append(sess.run(cfg.num_steps))
            return out

        run_loop()  # warmup outside the timed section
        warm_loop = loop_eng.trace_count
        t_loop, _ = time_call(run_loop, trials=trials, warmup=0)
        # All sweep configs share one static shape, so even the loop path
        # compiles once under the new cache — the launch count (and the
        # Θ(n_cfg) host dispatch/open overhead) is what the ensemble
        # eliminates. `compiles` records the cumulative trace count;
        # `traces_delta` (warm-section retraces, must stay 0) mirrors the
        # other BENCH_*.json artifacts so compile regressions are diffable
        # across PRs — the CI retrace check fails the build on a nonzero
        # delta.
        rows.append((
            f"scenarios/loop/{b}", t_loop * 1e6,
            f"events_per_s={total_events / t_loop:.4g};"
            f"compiles={loop_eng.trace_count};"
            f"launches={n_cfg * launches_per_run};configs={n_cfg};"
            f"traces_delta={loop_eng.trace_count - warm_loop}"))

        # --- ensemble path: one spec, one compile, one launch per chunk -
        ens_eng = Engine(b, chunk_size=chunk)

        def run_ensemble():
            with ens_eng.open(spec) as sess:
                return sess.run(spec.num_steps)

        run_ensemble()  # warmup outside the timed section
        warm_ens = ens_eng.trace_count
        t_ens, _ = time_call(run_ensemble, trials=trials, warmup=0)
        rows.append((
            f"scenarios/ensemble/{b}", t_ens * 1e6,
            f"events_per_s={total_events / t_ens:.4g};"
            f"compiles={ens_eng.trace_count};"
            f"launches={launches_per_run};markets={spec.num_markets};"
            f"speedup_vs_loop={t_loop / t_ens:.2f}x;"
            f"traces_delta={ens_eng.trace_count - warm_ens}"))

        rows.extend(_coupled_rows(b, markets * 4, agents, steps, chunk,
                                  trials))
    return rows


def _coupled_rows(backend: str, markets: int, agents: int, steps: int,
                  chunk: int, trials: int) -> List[Row]:
    """Cross-market coupling cost: events/s with the arbitrage halo
    exchange off vs on (same warm engine — coupling is a params value),
    and single-device vs 2-device sharded when the process has devices."""
    cfg = scenario_config("high-vol", num_markets=markets, num_agents=agents,
                          num_steps=steps, alpha_maker=0.15,
                          alpha_arbitrageur=0.25, seed=1)
    spec = EnsembleSpec.coerce(cfg)
    ring = CouplingSpec.ring(markets)
    events = spec.events()
    rows: List[Row] = []

    eng = Engine(backend, chunk_size=chunk)

    def run_spec(e, s):
        with e.open(s) as sess:
            return sess.run(s.num_steps)

    run_spec(eng, spec)  # warmup
    warm = eng.trace_count
    t_off, _ = time_call(run_spec, eng, CouplingSpec.none(markets).apply(spec),
                         trials=trials, warmup=0)
    t_on, _ = time_call(run_spec, eng, ring.apply(spec),
                        trials=trials, warmup=0)
    rows.append((
        f"scenarios/coupled/off/{backend}", t_off * 1e6,
        f"events_per_s={events / t_off:.4g};markets={markets};"
        f"traces_delta={eng.trace_count - warm}"))
    rows.append((
        f"scenarios/coupled/on/{backend}", t_on * 1e6,
        f"events_per_s={events / t_on:.4g};"
        f"coupling_overhead={t_on / t_off:.3f}x;"
        f"traces_delta={eng.trace_count - warm}"))

    # Sharded variant: jax-family engines only, and only when the process
    # actually has >= 2 devices (CI distributed tier sets XLA_FLAGS).
    if not backend.startswith("numpy"):
        import jax

        if len(jax.devices()) >= 2:
            sh_eng = Engine(backend, chunk_size=chunk, devices=2)
            coupled = ring.apply(spec)
            run_spec(sh_eng, coupled)  # warmup
            sh_warm = sh_eng.trace_count
            t_sh, _ = time_call(run_spec, sh_eng, coupled,
                                trials=trials, warmup=0)
            rows.append((
                f"scenarios/coupled/sharded/{backend}", t_sh * 1e6,
                f"events_per_s={events / t_sh:.4g};devices=2;"
                f"vs_single={t_sh / t_on:.3f}x;"
                f"traces_delta={sh_eng.trace_count - sh_warm}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS))
    ap.add_argument("--markets", type=int, default=None,
                    help="markets per (scenario, mixture) cell")
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--json", default=None, metavar="BENCH_scenario.json",
                    help="also write a machine-readable artifact")
    args = ap.parse_args()
    rows = run(backends=args.backends.split(","), markets=args.markets,
               agents=args.agents, steps=args.steps, trials=args.trials)
    emit(rows)
    if args.json:
        write_json(rows, args.json, "scenario_sweep")


if __name__ == "__main__":
    main()
