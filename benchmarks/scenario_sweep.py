"""Scenario-engine throughput: events/s per scenario preset and mixture.

Every scenario compiles to the same fully fused persistent kernel (overlays
are branch-free ``where`` selects on static config fields), so the paper's
headline throughput should be *scenario-invariant* — this sweep measures
exactly that, plus the cost of richer archetype mixtures. One warm Engine
per backend is shared across the whole sweep: each (scenario, mixture)
compiles once during warmup and every timed trial reuses the cached
executable through a fresh session.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (FIXED_A, FIXED_M, STEPS, Row, emit,
                               events_per_s, time_call)
from repro.core.config import scenario_config, scenario_names
from repro.core.session import Engine

BACKENDS = ["numpy", "jax-scan", "pallas-kinetic"]

MIXTURES = {
    "paper": dict(alpha_maker=0.15, alpha_momentum=0.15),
    "hetero4": dict(alpha_maker=0.10, alpha_momentum=0.20,
                    alpha_fundamentalist=0.25),
}


def run() -> List[Row]:
    engines = {b: Engine(b) for b in BACKENDS}
    rows = []
    for scenario in scenario_names():
        for mix_name, mix in MIXTURES.items():
            cfg = scenario_config(
                scenario, num_markets=FIXED_M, num_agents=FIXED_A,
                num_steps=STEPS, **mix)
            per_backend = {}
            for b in BACKENDS:
                eng = engines[b]

                def run_once():
                    with eng.open(cfg) as sess:
                        return sess.run(cfg.num_steps)

                t, _ = time_call(run_once, trials=3, warmup=1)
                per_backend[b] = t
                rows.append((
                    f"scenarios/{scenario}/{mix_name}/{b}",
                    t * 1e6,
                    f"events_per_s={events_per_s(cfg, t):.4g}"))
            k = per_backend["pallas-kinetic"]
            rows.append((
                f"scenarios/{scenario}/{mix_name}/speedups",
                k * 1e6,
                ";".join(f"vs_{b}={per_backend[b] / k:.2f}x"
                         for b in BACKENDS if b != "pallas-kinetic")))
    return rows


if __name__ == "__main__":
    emit(run(), benchmark="scenario_sweep")
