"""Paper Table V: peak memory footprint across the market sweep.

On this CPU container we report the *compiled buffer footprint* from XLA's
memory analysis (arguments + temps - aliased) per backend — the exact
quantity HBM residency is decided by on TPU — plus the analytical
global-memory model from the paper's §III-F:

  KineticSim   G = Theta(M*L)        (books in+out, stats; S-independent)
  Naive        G = Theta(S*M*L)      (books round-trip every step)
  Framework    G = Theta(S*M*L)      (+ materialized intermediates)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FIXED_A, MARKET_SWEEP, STEPS, emit
from repro.core.config import MarketConfig
from repro.core.step import initial_state
from repro.kernels import ref
from repro.kernels.kinetic_clearing import kinetic_clearing, pick_tile


def _compiled_footprint_scan(cfg) -> int:
    state = initial_state(cfg, jnp)
    lowered = ref._run.lower(state.bid, state.ask, state.last_price,
                             state.prev_mid, cfg=cfg, scan="cumsum")
    ma = lowered.compile().memory_analysis()
    return int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
               - ma.alias_size_in_bytes)


def analytical_bytes(cfg, backend: str) -> int:
    M, L, S, A = (cfg.num_markets, cfg.num_levels, cfg.num_steps,
                  cfg.num_agents)
    books = 2 * M * L * 4
    stats = 2 * M * S * 4  # price/volume paths
    if backend == "kinetic":
        return books + stats + 2 * M * 4          # Theta(M*L): on-chip books
    if backend == "naive":
        return 2 * books + stats + 7 * M * L * 4  # HBM books + step buffers
    # framework: all per-step intermediates live in device memory
    return 2 * books + stats + (7 * M * L + 3 * M * A) * 4


def run() -> list:
    rows = []
    for m in MARKET_SWEEP:
        cfg = MarketConfig(num_markets=m, num_agents=FIXED_A,
                           num_steps=min(STEPS, 50))
        fw = _compiled_footprint_scan(cfg)
        rows.append((f"tableV/M{m}/framework_compiled_bytes", 0.0, str(fw)))
        for b in ("kinetic", "naive", "framework"):
            rows.append((f"tableV/M{m}/{b}_analytical_bytes", 0.0,
                         str(analytical_bytes(cfg, b))))
        red = (analytical_bytes(cfg, "framework")
               / analytical_bytes(cfg, "kinetic"))
        rows.append((f"tableV/M{m}/reduction", 0.0, f"{red:.1f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
