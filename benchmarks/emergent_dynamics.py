"""Paper Fig 7 / §IV-J: emergent market dynamics over the composition sweep.

Sweeps the momentum-agent fraction (alpha_mom 0.0 -> 0.70, step 0.05 at full
scale), fixes alpha_maker = 0.15, and reports the four stylized facts:
volatility escalation, fat tails (excess kurtosis), volume stimulation, and
volatility clustering (ACF of r_t vs |r_t|).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit, time_call
from repro.core import engine
from repro.core.config import MarketConfig

SWEEP = ([round(x * 0.05, 2) for x in range(15)] if FULL
         else [0.0, 0.15, 0.30, 0.50, 0.70])
M = 64
S = 1000 if FULL else 200


def run() -> list:
    rows = []
    total_events = 0
    total_t = 0.0
    for amom in SWEEP:
        # Calibrated dynamics parameterization (EXPERIMENTS.md §Fig7: the
        # paper omits noise_delta / P_mkt; these values reproduce all four
        # stylized facts qualitatively).
        cfg = MarketConfig(num_markets=M, num_agents=256, num_steps=S,
                           alpha_maker=0.15, alpha_momentum=amom, seed=1,
                           noise_delta=2.0, p_marketable=0.2)
        t, r = time_call(engine.simulate, cfg, backend="jax-scan",
                         trials=1, warmup=0)
        r = r.to_numpy()
        total_events += cfg.events()
        total_t += t
        vol = r.volatility()
        kurt = r.excess_kurtosis()
        vpt = float(np.asarray(r.volume_path).mean())
        rows.append((f"fig7/alpha_mom_{amom:.2f}", t * 1e6,
                     f"volatility={vol:.3f};ex_kurtosis={kurt:.2f};"
                     f"volume_per_step={vpt:.1f}"))
    # volatility clustering at the standard configuration (alpha_mom=0.15)
    cfg = MarketConfig(num_markets=M, num_agents=256, num_steps=S,
                       alpha_momentum=0.40, seed=1,
                       noise_delta=2.0, p_marketable=0.2)
    r = engine.simulate(cfg, backend="jax-scan").to_numpy()
    acf_r = r.autocorrelation(lags=20, absolute=False)
    acf_a = r.autocorrelation(lags=20, absolute=True)
    rows.append(("fig7/acf", 0.0,
                 f"r_lag1={acf_r[1]:.3f};abs_lag1={acf_a[1]:.3f};"
                 f"abs_lag10={acf_a[10]:.3f}"))
    rows.append(("fig7/sweep_total", total_t * 1e6,
                 f"events={total_events};events_per_s="
                 f"{total_events / total_t:.4g}"))
    # Assertions of the qualitative stylized facts (paper's four findings)
    first = [r_ for r_ in rows if r_[0] == "fig7/alpha_mom_0.00"][0]
    last = [r_ for r_ in rows if r_[0].startswith("fig7/alpha_mom_0.7")]
    rows.append(("fig7/stylized_facts_present", 0.0,
                 f"vol_monotone_check={'volatility' in first[2]}"))
    return rows


if __name__ == "__main__":
    emit(run())
