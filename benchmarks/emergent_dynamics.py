"""Paper Fig 7 / §IV-J: emergent market dynamics over the composition sweep.

Sweeps the momentum-agent fraction (alpha_mom 0.0 -> 0.70, step 0.05 at full
scale), fixes alpha_maker = 0.15, and reports the four stylized facts:
volatility escalation, fat tails (excess kurtosis), volume stimulation, and
volatility clustering (ACF of r_t vs |r_t|).

The per-configuration measurement grew into the scenario validation
subsystem and now lives in :mod:`repro.scenario.validate`; this module
re-exports :func:`stylized_facts` and the pinned smoke configuration so
existing imports (tests/test_emergent.py, downstream notebooks) keep
working. New code should import from ``repro.scenario.validate`` directly —
that module adds the typed :class:`~repro.scenario.validate.FactCheck` /
:class:`~repro.scenario.validate.ValidationReport` gate that CI runs via
``benchmarks/scenario_realism.py``.
"""
from __future__ import annotations

import argparse

from benchmarks.common import FULL, emit, time_call
from repro.core import engine
from repro.core.config import MarketConfig
from repro.scenario.validate import (  # noqa: F401  (re-exports)
    high_vol_momentum_config,
    stylized_facts,
)

SWEEP = ([round(x * 0.05, 2) for x in range(15)] if FULL
         else [0.0, 0.15, 0.30, 0.50, 0.70])
M = 64
S = 1000 if FULL else 200


def high_vol_smoke_config(num_steps: int = 500) -> MarketConfig:
    """The configuration the slow stylized-facts smoke pins.

    Alias of :func:`repro.scenario.validate.high_vol_momentum_config` —
    the same pinned mixture the CI realism gate validates.
    """
    return high_vol_momentum_config(num_steps)


def _sweep_config(amom: float) -> MarketConfig:
    # Calibrated dynamics parameterization (EXPERIMENTS.md §Fig7: the
    # paper omits noise_delta / P_mkt; these values reproduce all four
    # stylized facts qualitatively).
    return MarketConfig(num_markets=M, num_agents=256, num_steps=S,
                        alpha_maker=0.15, alpha_momentum=amom, seed=1,
                        noise_delta=2.0, p_marketable=0.2)


def run(backend: str = "jax-scan") -> list:
    rows = []
    total_events = 0
    total_t = 0.0
    for amom in SWEEP:
        cfg = _sweep_config(amom)
        t, _ = time_call(engine.simulate, cfg, backend=backend,
                         trials=1, warmup=0)
        facts = stylized_facts(cfg, backend=backend)
        total_events += cfg.events()
        total_t += t
        rows.append((f"fig7/alpha_mom_{amom:.2f}", t * 1e6,
                     f"volatility={facts['volatility']:.3f};"
                     f"ex_kurtosis={facts['excess_kurtosis']:.2f};"
                     f"volume_per_step={facts['volume_per_step']:.1f};"
                     f"vv_corr={facts['volume_volatility_corr']:.3f}"))
    # volatility clustering at the momentum-heavy configuration
    facts = stylized_facts(MarketConfig(
        num_markets=M, num_agents=256, num_steps=S, alpha_momentum=0.40,
        seed=1, noise_delta=2.0, p_marketable=0.2), backend=backend)
    rows.append(("fig7/acf", 0.0,
                 f"r_lag1={facts['acf_r_lag1']:.3f};"
                 f"abs_lag1={facts['acf_abs_lag1']:.3f};"
                 f"abs_lag10={facts['acf_abs_lag10']:.3f}"))
    # high-vol preset: the configuration the smoke test pins (fat tails +
    # positive volume/volatility correlation)
    facts = stylized_facts(high_vol_smoke_config(), backend=backend)
    rows.append(("fig7/high_vol_preset", 0.0,
                 f"kurtosis={facts['kurtosis']:.2f};"
                 f"vv_corr={facts['volume_volatility_corr']:.3f}"))
    rows.append(("fig7/sweep_total", total_t * 1e6,
                 f"events={total_events};events_per_s="
                 f"{total_events / total_t:.4g}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jax-scan")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ns = ap.parse_args()
    emit(run(backend=ns.backend), json_path=ns.json,
         benchmark="emergent_dynamics")
