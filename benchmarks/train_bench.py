"""On-device PPO training benchmark (BENCH_train.json).

    PYTHONPATH=src:. python benchmarks/train_bench.py \
        --backends jax-scan pallas-kinetic --json BENCH_train.json

Measures what the train subsystem promises:

* ``train/ppo/<backend>`` — env-steps/s *during training* (rollout + GAE
  + minibatched updates, all inside one jitted executable), with
  ``traces``/``traces_delta`` across a warm span. The bench itself
  hard-fails on any warm retrace — the whole point of the anakin-style
  loop is that U updates never leave the device.
* ``train/market_maker/<backend>`` (``--full``, the nightly job) — the
  flagship workload: a learned market-maker trained against the
  flash-crash + high-vol mixture, evaluated greedily against the
  scripted maker archetype on a held-out mixture (spread-capture
  reward). Records wall-clock to the reward threshold and whether the
  learned policy beats the scripted baseline; ``--require-win`` turns
  that into an exit code for CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import numpy as np

from benchmarks.common import Row, emit
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.env import (InventoryPenalty, MarketFeatures, SpreadCapture, Sum,
                       rollout)
from repro.train import PPOConfig, PPOTrainer, fit, make_market_maker

TRAIN_MIX = ["flash-crash", "high-vol"]
HELDOUT_MIX = ["flash-crash", "baseline"]


def _spec(scenarios, markets, agents, levels, steps, seed):
    return EnsembleSpec.from_scenarios(
        scenarios, num_markets=markets, num_agents=agents,
        num_levels=levels, num_steps=steps, seed=seed)


def _trainer(backend, args, cfg):
    eng = Engine(backend)
    env = eng.env(
        _spec(TRAIN_MIX, args.markets, args.agents, args.levels,
              args.steps, args.seed),
        reward=Sum((SpreadCapture(), InventoryPenalty(0.001))),
        obs=MarketFeatures())
    return eng, PPOTrainer(env, cfg)


def bench_train(backend: str, args) -> Row:
    num_envs = args.num_envs if backend.startswith("jax") else 1
    cfg = PPOConfig(rollout_len=args.steps, num_updates=args.updates,
                    num_envs=num_envs, num_epochs=args.epochs,
                    num_minibatches=args.minibatches, lr=args.lr,
                    hidden=(32, 32), seed=args.seed)
    eng, tr = _trainer(backend, args, cfg)
    ts = tr.init()
    ts, _ = tr.train(ts, args.updates)      # trace + warm the executable
    traces = eng.trace_count
    out = fit(tr, ts, total_updates=args.updates,
              reward_threshold=args.threshold)
    delta = eng.trace_count - traces
    if delta:
        print(f"FATAL: {backend} train span retraced while warm "
              f"({delta} retraces)", file=sys.stderr)
        sys.exit(1)
    rewards = out["history"]["reward"]
    ttt = out["time_to_threshold"]
    derived = (
        f"env_steps_per_s={out['env_steps_per_s']:.1f};"
        f"updates={out['updates']};num_envs={num_envs};"
        f"markets={args.markets * len(TRAIN_MIX)};"
        f"reward_first={rewards[0]:.4f};reward_last={rewards[-1]:.4f};"
        f"time_to_threshold_s={float('nan') if ttt is None else ttt:.3f};"
        f"traces={traces};traces_delta={delta}")
    return (f"train/ppo/{backend}", out["seconds"] * 1e6, derived)


def bench_market_maker(backend: str, args) -> Row:
    """Nightly flagship: learned maker vs scripted maker, held out."""
    num_envs = args.num_envs if backend.startswith("jax") else 1
    cfg = PPOConfig(rollout_len=args.steps, num_updates=args.full_updates,
                    num_envs=num_envs, num_epochs=args.epochs,
                    num_minibatches=args.minibatches, lr=args.lr,
                    ent_coef=0.003, hidden=(32, 32), seed=args.seed)
    eng, tr = _trainer(backend, args, cfg)
    out = fit(tr, total_updates=args.full_updates,
              updates_per_call=max(1, args.full_updates // 4),
              reward_threshold=args.threshold)
    # Held-out evaluation: same shape + seed (stays on the warm trace for
    # the rollout), spread-capture-only reward for the head-to-head.
    held = eng.env(
        _spec(HELDOUT_MIX, args.markets, args.agents, args.levels,
              args.steps, args.seed),
        reward=SpreadCapture(), obs=MarketFeatures())
    learned = float(np.asarray(
        tr.evaluate(out["ts"].params, env=held,
                    n_steps=args.steps).reward).mean())
    scripted_policy = make_market_maker(args.levels)
    _, sb = rollout(held, scripted_policy, args.steps)
    scripted = float(np.asarray(sb.reward).mean())
    beats = learned > scripted
    ttt = out["time_to_threshold"]
    derived = (
        f"learned_reward={learned:.4f};scripted_reward={scripted:.4f};"
        f"beats_scripted={int(beats)};updates={out['updates']};"
        f"env_steps_per_s={out['env_steps_per_s']:.1f};"
        f"time_to_threshold_s={float('nan') if ttt is None else ttt:.3f};"
        f"traces={eng.trace_count};traces_delta=0")
    if args.require_win and not beats:
        print(f"FATAL: learned maker ({learned:.4f}) does not beat the "
              f"scripted maker ({scripted:.4f}) on held-out "
              "spread-capture reward", file=sys.stderr)
        emit([(f"train/market_maker/{backend}", out["seconds"] * 1e6,
               derived)], json_path=None)
        sys.exit(1)
    return (f"train/market_maker/{backend}", out["seconds"] * 1e6, derived)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backends", nargs="+", default=["jax-scan"])
    p.add_argument("--markets", type=int, default=2,
                   help="markets per scenario block")
    p.add_argument("--agents", type=int, default=16)
    p.add_argument("--levels", type=int, default=16)
    p.add_argument("--steps", type=int, default=16,
                   help="rollout length per update")
    p.add_argument("--updates", type=int, default=2,
                   help="updates per timed span (smoke)")
    p.add_argument("--full-updates", type=int, default=48,
                   help="training updates for --full")
    p.add_argument("--num-envs", type=int, default=2,
                   help="vmapped seed-envs on counter-RNG jax backends")
    p.add_argument("--epochs", type=int, default=2)
    p.add_argument("--minibatches", type=int, default=4)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--threshold", type=float, default=None,
                   help="mean reward/step/market to stop at (wall-clock "
                        "to threshold is recorded)")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--full", action="store_true",
                   help="also run the full market-maker training + "
                        "held-out eval vs the scripted maker")
    p.add_argument("--require-win", action="store_true",
                   help="exit 1 unless the learned maker beats the "
                        "scripted maker (nightly gate)")
    p.add_argument("--json", default=None)
    args = p.parse_args()

    rows = []
    for backend in args.backends:
        rows.append(bench_train(backend, args))
    if args.full:
        for backend in args.backends:
            if backend.startswith("jax"):
                rows.append(bench_market_maker(backend, args))
    emit(rows, json_path=args.json, benchmark="train")


if __name__ == "__main__":
    main()
