"""Paper Table II + §IV-C: cross-backend semantic equivalence.

Reports mean clearing price / volume per market per backend, the relative
error vs the CPU (NumPy) reference, and whether the kinetic-RNG backends are
bitwise identical. Also runs the analytical L=5 clearing case on every
backend (paper Eq. 11-18).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FIXED_A, FIXED_M, STEPS, Row, emit, time_call
from repro.core import auction, engine
from repro.core.config import MarketConfig


def analytical_case_all_backends() -> bool:
    import jax.numpy as jnp

    BUY = np.array([[10.0, 5.0, 8.0, 0.0, 2.0]], dtype=np.float32)
    SELL = np.array([[0.0, 4.0, 7.0, 6.0, 3.0]], dtype=np.float32)
    ok = True
    for xp, tag in ((np, "numpy"), (jnp, "jax")):
        for scan in ("cumsum", "hillis-steele"):
            c = auction.clear(xp.asarray(BUY), xp.asarray(SELL), xp, scan=scan)
            ok &= int(c["p_star"][0, 0]) == 2
            ok &= float(c["volume"][0, 0]) == 10.0
            ok &= np.allclose(np.asarray(c["new_bid"]), [[10, 5, 0, 0, 0]])
            ok &= np.allclose(np.asarray(c["new_ask"]), [[0, 0, 1, 6, 3]])
    return ok


def run() -> list:
    cfg = MarketConfig(num_markets=min(FIXED_M, 256), num_agents=FIXED_A,
                       num_steps=min(STEPS, 50), seed=0)
    rows: list = []
    ref = engine.simulate(cfg, backend="numpy").to_numpy()
    ref_px, ref_vol = ref.mean_clearing_price(), ref.volume_per_market()
    rows.append(("tableII/analytical_case_ok", 0.0,
                 str(analytical_case_all_backends())))

    backends = ["numpy", "jax-scan", "jax-per-step", "pallas-naive",
                "pallas-kinetic", "numpy-splitmix64", "numpy-pcg64"]
    for b in backends:
        t, r = time_call(engine.simulate, cfg, backend=b, trials=1, warmup=0)
        r = r.to_numpy()
        px, vol = r.mean_clearing_price(), r.volume_per_market()
        bitwise = bool((r.bid == ref.bid).all() and (r.ask == ref.ask).all()
                       and (r.price_path == ref.price_path).all())
        rel = abs(px - ref_px) / ref_px
        rows.append((f"tableII/{b}/clearing_px", t * 1e6,
                     f"px={px:.3f};vol={vol:.1f};rel_err={rel:.5f};"
                     f"bitwise={bitwise}"))
    return rows


if __name__ == "__main__":
    emit(run())
