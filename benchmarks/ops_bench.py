"""Ops-surface benchmark: warm-start latency + metrics overhead.

Two row families, emitted to ``BENCH_ops.json`` for the CI trajectory:

* ``ops/warm_start/<backend>`` — wall time of ``Engine.warm()`` (the cold
  compile cost a deployment pays up front) vs the steady-state chunk
  latency afterwards, plus the number of executables compiled.  The run
  **fails** if the first post-warm session retraces (``traces_delta`` must
  be 0): warm() promising readiness and then retracing is a regression.
* ``ops/metrics_overhead/<backend>`` — steady-state chunk latency with the
  metrics registry off vs on.  Metrics sample host-side around dispatch
  (the zero-hot-path guarantee), so the delta is pure host bookkeeping and
  ``traces_delta`` must again be 0.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import FULL, Row, emit
from repro.core.config import MarketConfig
from repro.core.session import Engine

BACKENDS = ["numpy-pcg64", "jax-scan", "pallas-kinetic"]
M = 1024 if FULL else 64
A = 256 if FULL else 64
S = 512 if FULL else 128


def _cfg() -> MarketConfig:
    return MarketConfig(num_markets=M, num_agents=A, num_steps=S, seed=1)


def _median_run_us(eng: Engine, cfg: MarketConfig, *, metrics: bool,
                   trials: int) -> float:
    times = []
    for _ in range(trials):
        sess = eng.open(cfg, metrics=metrics)
        t0 = time.perf_counter()
        batch = sess.run(cfg.num_steps)
        np.asarray(batch.to_numpy().price)  # materialize on host
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def warm_start_rows(backends, trials: int) -> list:
    rows: list[Row] = []
    for backend in backends:
        cfg = _cfg()
        eng = Engine(backend)
        t0 = time.perf_counter()
        ready = eng.warm(cfg)
        cold_us = (time.perf_counter() - t0) * 1e6
        assert ready.ready, f"{backend}: warm() left cold keys"
        traces = eng.trace_count
        warm_us = _median_run_us(eng, cfg, metrics=False, trials=trials)
        delta = eng.trace_count - traces
        if delta != 0:
            raise AssertionError(
                f"{backend}: {delta} retrace(s) after warm() — the "
                f"warm-start contract is broken")
        rows.append((f"ops/warm_start/{backend}", cold_us,
                     f"cold_us={cold_us:.0f};warm_us={warm_us:.1f};"
                     f"traces={traces};traces_delta={delta}"))
    return rows


def metrics_overhead_rows(backends, trials: int) -> list:
    rows: list[Row] = []
    for backend in backends:
        cfg = _cfg()
        eng = Engine(backend)
        eng.warm(cfg, include_step=False)
        off_us = _median_run_us(eng, cfg, metrics=False, trials=trials)
        traces = eng.trace_count
        on_us = _median_run_us(eng, cfg, metrics=True, trials=trials)
        delta = eng.trace_count - traces
        if delta != 0:
            raise AssertionError(
                f"{backend}: metrics collection caused {delta} retrace(s) — "
                f"the zero-hot-path guarantee is broken")
        overhead = 100.0 * (on_us - off_us) / off_us if off_us else 0.0
        rows.append((f"ops/metrics_overhead/{backend}", on_us,
                     f"off_us={off_us:.1f};on_us={on_us:.1f};"
                     f"overhead_pct={overhead:.2f};traces_delta={delta}"))
    return rows


def run(backends=None, trials: int = 5) -> list:
    backends = backends or BACKENDS
    return warm_start_rows(backends, trials) + \
        metrics_overhead_rows(backends, trials)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", nargs="*", default=BACKENDS)
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ns = ap.parse_args()
    emit(run(ns.backends, ns.trials), json_path=ns.json, benchmark="ops")
