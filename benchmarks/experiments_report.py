"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the result
cache. Run after a dry-run sweep:

    PYTHONPATH=src python -m benchmarks.experiments_report > /tmp/tables.md
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_t(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.0f}us"


def load(mesh_tag):
    recs = []
    for f in sorted(RESULTS.glob(f"*__{mesh_tag}.json")):
        try:
            recs.append(json.loads(f.read_text()))
        except Exception:
            pass
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return recs


def dryrun_table(mesh_tag):
    rows = ["| arch | shape | status | compile | args/dev | temp/dev | "
            "fits 16G | collectives (AR/AG/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load(mesh_tag):
        if r["status"] != "OK":
            reason = r.get("reason", r.get("error", ""))[:46]
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                        f"({reason}) | | | | | |")
            continue
        pd = r["per_device"]
        cc = pd["collective_counts"]
        cstr = "/".join(str(int(cc[k])) for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK | {r['compile_s']:.0f}s "
            f"| {fmt_bytes(pd['argument_bytes'])} "
            f"| {fmt_bytes(pd['temp_bytes'])} "
            f"| {'Y' if r['hbm_fits_16g'] else 'N'} | {cstr} |")
    return "\n".join(rows)


def roofline_table(mesh_tag):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "bound/step | MODEL_FLOPS/HLO | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh_tag):
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | "
                        f"{r['reason'][:60]} |")
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        rf, mdl = r["roofline"], r["model"]
        note = ""
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(rf['compute_s'])} "
            f"| {fmt_t(rf['memory_s'])} | {fmt_t(rf['collective_s'])} "
            f"| **{rf['dominant']}** | {fmt_t(rf['step_time_bound_s'])} "
            f"| {mdl['useful_fraction']:.3f} | {note} |")
    return "\n".join(rows)


def main():
    for tag, label in (("pod_16x16", "single pod 16x16 (256 chips)"),
                       ("multipod_2x16x16", "multi-pod 2x16x16 (512 chips)")):
        recs = load(tag)
        n_ok = sum(r["status"] == "OK" for r in recs)
        n_skip = sum(r["status"] == "SKIP" for r in recs)
        print(f"\n### Dry-run — {label}: {n_ok} OK, {n_skip} SKIP, "
              f"{len(recs) - n_ok - n_skip} other\n")
        print(dryrun_table(tag))
    print("\n### Roofline — single pod (roofline table is single-pod only)\n")
    print(roofline_table("pod_16x16"))


if __name__ == "__main__":
    main()
