"""Paper Table III / Fig 3: throughput across market and agent sweeps.

Throughput = M*A*S / wall_time (agent-events/s), per backend, with
KineticSim speedups vs each baseline — the paper's exact report structure
at CPU-tractable scale (see common.FULL).

Beyond the paper's single-device table this sweep also records the *sharded*
regime: when the process has >= 2 devices (real TPUs, or CPU hosts forced
via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``), the Pallas
engines re-run with the ensemble sharded over all devices at equal
per-device M (weak scaling), reporting per-device events/s and the
weak-scaling efficiency vs the unsharded baseline.

    PYTHONPATH=src python -m benchmarks.throughput_sweep \
        --backends numpy,jax-scan,pallas-kinetic --markets 16,64 \
        --json bench/BENCH_throughput.json

``--json`` writes the machine-readable ``BENCH_throughput.json`` artifact
uploaded by CI next to ``BENCH_latency.json`` (the perf trajectory record).
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from benchmarks.common import (AGENT_SWEEP, FIXED_A, FIXED_M, MARKET_SWEEP,
                               STEPS, Row, emit, events_per_s, time_call)
from repro.core.config import MarketConfig
from repro.core.session import Engine

BACKENDS = ["numpy", "jax-per-step", "jax-scan", "pallas-naive",
            "pallas-kinetic"]
SHARDABLE = ("pallas-kinetic", "pallas-naive")


def _device_count() -> int:
    import jax

    return len(jax.devices())


def _time_session_run(eng: Engine, cfg: MarketConfig, trials: int) -> float:
    """Median wall time of a full warm-engine session run (compile excluded
    by the warmup call; re-opening a session reuses cached executables)."""

    def once():
        with eng.open(cfg) as sess:
            return sess.run(cfg.num_steps)

    t, _ = time_call(once, trials=trials, warmup=1)
    return t


def _sweep(tag: str, configs, backends, engines, trials: int) -> List[Row]:
    rows: List[Row] = []
    for cfg in configs:
        per_backend = {}
        for b in backends:
            t = _time_session_run(engines[b], cfg, trials)
            per_backend[b] = t
            rows.append((
                f"tableIII/{tag}/M{cfg.num_markets}_A{cfg.num_agents}/{b}",
                t * 1e6,
                f"events_per_s={events_per_s(cfg, t):.4g}"))
        if "pallas-kinetic" in per_backend and len(per_backend) > 1:
            k = per_backend["pallas-kinetic"]
            rows.append((
                f"tableIII/{tag}/M{cfg.num_markets}_A{cfg.num_agents}/speedups",
                k * 1e6,
                ";".join(f"vs_{b}={per_backend[b] / k:.2f}x"
                         for b in per_backend if b != "pallas-kinetic")))
    return rows


def _sharded_sweep(markets, backends, engines, trials: int,
                   stats_only: bool) -> List[Row]:
    """Weak scaling: D devices at equal per-device M (total M scales by D).

    Reports per-device events/s for both layouts; ``weak_scaling=`` is the
    sharded per-device rate over the unsharded rate (1.0 = perfect). On
    CPU runners with forced host devices the "devices" share physical
    cores, so treat those numbers as plumbing checks, not speedups.
    """
    devices = _device_count()
    if devices < 2:
        return [("tableIII/sharded/skipped", 0.0,
                 "reason=single_device;hint=XLA_FLAGS="
                 "--xla_force_host_platform_device_count=N")]
    rows: List[Row] = []
    opts = {"stats_only": True} if stats_only else {}
    mode = "stats_only" if stats_only else "paths"
    for b in backends:
        if b not in SHARDABLE:
            continue
        # Default mode reuses the warm engines _sweep already compiled;
        # stats_only runners need their own executables.
        single_eng = Engine(b, **opts) if stats_only else engines[b]
        sharded_eng = Engine(b, devices=devices, **opts)
        for m in markets:
            base = MarketConfig(num_markets=m, num_agents=FIXED_A,
                                num_steps=STEPS)
            total = MarketConfig(num_markets=m * devices, num_agents=FIXED_A,
                                 num_steps=STEPS)
            t1 = _time_session_run(single_eng, base, trials)
            td = _time_session_run(sharded_eng, total, trials)
            per_dev_single = events_per_s(base, t1)
            per_dev_sharded = events_per_s(total, td) / devices
            rows.append((
                f"tableIII/sharded/M{m}xD{devices}_A{FIXED_A}/{b}/{mode}",
                td * 1e6,
                f"events_per_s={events_per_s(total, td):.4g};"
                f"per_device_events_per_s={per_dev_sharded:.4g};"
                f"single_device_events_per_s={per_dev_single:.4g};"
                f"weak_scaling={per_dev_sharded / per_dev_single:.3f};"
                f"devices={devices}"))
    return rows


def run(backends=BACKENDS, markets: Optional[List[int]] = None,
        agents: Optional[List[int]] = None, trials: int = 3,
        stats_only: bool = False) -> List[Row]:
    markets = MARKET_SWEEP if markets is None else markets
    agents = AGENT_SWEEP if agents is None else agents
    engines = {b: Engine(b) for b in backends}
    market_cfgs = [MarketConfig(num_markets=m, num_agents=FIXED_A,
                                num_steps=STEPS) for m in markets]
    agent_cfgs = [MarketConfig(num_markets=FIXED_M, num_agents=a,
                               num_steps=STEPS) for a in agents]
    return (_sweep("markets", market_cfgs, backends, engines, trials)
            + _sweep("agents", agent_cfgs, backends, engines, trials)
            + _sharded_sweep(markets, backends, engines, trials, stats_only))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default=",".join(BACKENDS),
                    help="comma-separated backend list")
    ap.add_argument("--markets", default=None,
                    help="comma-separated M sweep (default: common.MARKET_SWEEP)")
    ap.add_argument("--agents", default=None,
                    help="comma-separated A sweep (default: common.AGENT_SWEEP)")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--stats-only", action="store_true",
                    help="run the sharded section in stats_only mode "
                         "(Θ(M) output traffic)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact "
                         "(BENCH_throughput.json)")
    args = ap.parse_args()
    parse_ints = lambda s: [int(x) for x in s.split(",") if x] if s else None
    rows = run(backends=[b for b in args.backends.split(",") if b],
               markets=parse_ints(args.markets),
               agents=parse_ints(args.agents),
               trials=args.trials, stats_only=args.stats_only)
    emit(rows, json_path=args.json, benchmark="throughput")


if __name__ == "__main__":
    main()
