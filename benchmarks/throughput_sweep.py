"""Paper Table III / Fig 3: throughput across market and agent sweeps.

Throughput = M*A*S / wall_time (agent-events/s), per backend, with
KineticSim speedups vs each baseline — the paper's exact report structure
at CPU-tractable scale (see common.FULL).
"""
from __future__ import annotations

from benchmarks.common import (AGENT_SWEEP, FIXED_A, FIXED_M, MARKET_SWEEP,
                               STEPS, emit, events_per_s, time_call)
from repro.core import engine
from repro.core.config import MarketConfig

BACKENDS = ["numpy", "jax-per-step", "jax-scan", "pallas-naive",
            "pallas-kinetic"]


def _sweep(tag, configs) -> list:
    rows = []
    for cfg in configs:
        per_backend = {}
        for b in BACKENDS:
            t, _ = time_call(engine.simulate, cfg, backend=b, trials=3,
                             warmup=1)
            per_backend[b] = t
            rows.append((
                f"tableIII/{tag}/M{cfg.num_markets}_A{cfg.num_agents}/{b}",
                t * 1e6,
                f"events_per_s={events_per_s(cfg, t):.4g}"))
        k = per_backend["pallas-kinetic"]
        rows.append((
            f"tableIII/{tag}/M{cfg.num_markets}_A{cfg.num_agents}/speedups",
            k * 1e6,
            ";".join(f"vs_{b}={per_backend[b] / k:.2f}x"
                     for b in BACKENDS if b != "pallas-kinetic")))
    return rows


def run() -> list:
    market_cfgs = [MarketConfig(num_markets=m, num_agents=FIXED_A,
                                num_steps=STEPS) for m in MARKET_SWEEP]
    agent_cfgs = [MarketConfig(num_markets=FIXED_M, num_agents=a,
                               num_steps=STEPS) for a in AGENT_SWEEP]
    return (_sweep("markets", market_cfgs) + _sweep("agents", agent_cfgs))


if __name__ == "__main__":
    emit(run())
