"""Paper §III-F: work-depth accounting, verified against the implementation.

Analytical terms for the benchmark configuration plus a structural check
that the Hillis-Steele scan in the kernel really is log2(L) strided stages
(the code unrolls one stage per power of two).
"""
from __future__ import annotations

import math

from benchmarks.common import FIXED_A, FIXED_M, LEVELS, STEPS, emit
from repro.core import auction


def run() -> list:
    M, A, L, S = FIXED_M, FIXED_A, LEVELS, STEPS
    rows = []
    naive_depth = S * (L + A)
    kinetic_depth = S * (int(math.log2(L)) + math.ceil(A / L))
    rows.append(("work_depth/naive/depth_total", 0.0, str(naive_depth)))
    rows.append(("work_depth/kinetic/depth_total", 0.0, str(kinetic_depth)))
    rows.append(("work_depth/depth_reduction", 0.0,
                 f"{naive_depth / kinetic_depth:.1f}x"))
    rows.append(("work_depth/naive/global_traffic_bytes", 0.0,
                 str(S * M * L * 4 * 2)))
    rows.append(("work_depth/kinetic/global_traffic_bytes", 0.0,
                 str(M * L * 4 * 2)))
    rows.append(("work_depth/traffic_reduction", 0.0, f"{S}x (=S)"))

    # structural check: H-S scan stage count == log2(L)
    import numpy as np

    stages = 0
    off = 1
    while off < L:
        stages += 1
        off *= 2
    x = np.random.RandomState(0).randint(0, 5, (1, L)).astype(np.float32)
    assert (auction.hillis_steele_prefix(x, np)
            == auction.prefix_sum(x, np)).all()
    rows.append(("work_depth/hillis_steele_stages", 0.0,
                 f"{stages} (=log2({L}))"))
    return rows


if __name__ == "__main__":
    emit(run())
