"""Paper Table IV: fixed reference workload, all backends.

Reports wall time, events/s, ns/event (the paper's amortized-cost metric,
Fig 5 right) and speedups vs every baseline. Runs through warm sessions:
the Engine compiles each backend's chunk executable once during warmup,
then every timed trial opens a fresh session on the cached executable —
so the numbers measure the warm execution path (state init + S steps),
not tracing.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (FIXED_A, FIXED_M, STEPS, Row, emit,
                               events_per_s, time_call)
from repro.core.config import MarketConfig
from repro.core.session import Engine

BACKENDS = ["numpy", "jax-per-step", "jax-scan", "pallas-naive",
            "pallas-kinetic"]


def run() -> List[Row]:
    cfg = MarketConfig(num_markets=FIXED_M, num_agents=FIXED_A,
                       num_steps=STEPS)
    rows, times = [], {}
    for b in BACKENDS:
        eng = Engine(b)

        def run_once():
            with eng.open(cfg) as sess:
                return sess.run(cfg.num_steps)

        t, _ = time_call(run_once, trials=3, warmup=1)
        times[b] = t
        rows.append((f"tableIV/{b}", t * 1e6,
                     f"events_per_s={events_per_s(cfg, t):.4g};"
                     f"ns_per_event={t * 1e9 / cfg.events():.4f}"))
    k = times["pallas-kinetic"]
    rows.append(("tableIV/speedups", k * 1e6,
                 ";".join(f"vs_{b}={times[b] / k:.2f}x"
                          for b in BACKENDS if b != "pallas-kinetic")))
    return rows


if __name__ == "__main__":
    emit(run(), benchmark="fixed_workload")
