"""Serving-gateway load benchmark: N concurrent streaming clients.

Drives :class:`repro.serve.Gateway` with a load-generating swarm of
in-process clients (the same transport the tests use — no sockets, so the
numbers isolate gateway/engine cost from kernel TCP) and emits
``BENCH_serve.json`` rows:

* ``serve/attach/<backend>/c<N>``  — admission throughput: sessions/s from
  first ``open_session`` to every client holding its first frame (slot
  splice + warm-trace reuse; no compile on this path, ever);
* ``serve/stream/<backend>/c<N>``  — steady-state fan-out: aggregate
  frames/s delivered across all clients, with the gateway's bounded-window
  per-chunk p50/p99 latency.

Every row asserts ``traces_delta == 0`` after warmup — a serving gateway
that retraces under client churn is a regression, and CI's
retrace-regression check reads these fields from the JSON artifact.
"""
from __future__ import annotations

import argparse
import asyncio
import time

from benchmarks.common import FULL, Row, emit
from repro.serve import Gateway, parked_template

BACKENDS = ["jax-scan", "pallas-kinetic"]
CLIENT_SWEEP = [8, 32, 128] if FULL else [8, 32]
A = 256 if FULL else 32
L = 128 if FULL else 32
CHUNK = 32 if FULL else 8
SCENARIOS = ["baseline", "flash-crash", "high-vol", "thin-book"]


async def _drive(backend: str, n_clients: int, frames_per_client: int):
    tpl = parked_template(slots=n_clients, num_agents=A, num_levels=L,
                          num_steps=1_000_000)
    gw = Gateway(tpl, backend=backend, chunk_size=CHUNK,
                 queue_maxsize=frames_per_client + 4)
    # +2 chunks: one for the lag-one pipeline, one for attach alignment
    await gw.start(chunks=frames_per_client + 2)

    t0 = time.perf_counter()
    clients = [gw.open_session(SCENARIOS[i % len(SCENARIOS)],
                               client=f"load-{i}")
               for i in range(n_clients)]
    await asyncio.gather(*(c.frames(1) for c in clients))
    attach_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    streams = await asyncio.gather(
        *(c.frames(frames_per_client - 1) for c in clients))
    stream_s = time.perf_counter() - t1
    n_frames = n_clients + sum(len(s) for s in streams)
    steps = sum(f.num_steps for s in streams for f in s)

    lat = gw.metrics.window("chunk_latency_seconds").summary()
    delta = gw.traces_delta
    await gw.stop()
    if delta != 0:
        raise AssertionError(
            f"{backend}/c{n_clients}: {delta} retrace(s) while serving — "
            "the warm-serving contract is broken")
    return {
        "attach_s": attach_s, "stream_s": stream_s, "frames": n_frames,
        "steps": steps, "p50_ms": lat["p50"] * 1e3,
        "p99_ms": lat["p99"] * 1e3, "traces_delta": delta,
    }


def run(backends=None, clients=None, frames: int = 40) -> list:
    rows: list[Row] = []
    for backend in backends or BACKENDS:
        for n in clients or CLIENT_SWEEP:
            r = asyncio.run(_drive(backend, n, frames))
            sessions_per_s = n / r["attach_s"] if r["attach_s"] else 0.0
            frames_per_s = (r["frames"] / r["stream_s"]
                            if r["stream_s"] else 0.0)
            rows.append((
                f"serve/attach/{backend}/c{n}", r["attach_s"] * 1e6,
                f"clients={n};sessions_per_s={sessions_per_s:.1f};"
                f"traces_delta={r['traces_delta']}"))
            rows.append((
                f"serve/stream/{backend}/c{n}", r["stream_s"] * 1e6,
                f"clients={n};frames_per_s={frames_per_s:.1f};"
                f"steps_per_s={r['steps'] / r['stream_s']:.0f};"
                f"chunk_p50_ms={r['p50_ms']:.3f};"
                f"chunk_p99_ms={r['p99_ms']:.3f};"
                f"traces_delta={r['traces_delta']}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", nargs="*", default=BACKENDS)
    ap.add_argument("--clients", nargs="*", type=int, default=CLIENT_SWEEP)
    ap.add_argument("--frames", type=int, default=40,
                    help="frames each client consumes")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ns = ap.parse_args()
    emit(run(ns.backends, ns.clients, ns.frames), json_path=ns.json,
         benchmark="serve")
