"""Serving-gateway load benchmark: N concurrent streaming clients.

Drives :class:`repro.serve.Gateway` with a load-generating swarm of
in-process clients (the same transport the tests use — no sockets, so the
numbers isolate gateway/engine cost from kernel TCP) and emits
``BENCH_serve.json`` rows:

* ``serve/attach/<backend>/c<N>``  — admission throughput: sessions/s from
  first ``open_session`` to every client holding its first frame (slot
  splice + warm-trace reuse; no compile on this path, ever);
* ``serve/stream/<backend>/c<N>``  — steady-state fan-out: aggregate
  frames/s delivered across all clients, with the gateway's bounded-window
  per-chunk p50/p99 latency;
* ``serve/ckpt/<backend>/c<N>``    — durability overhead: the same stream
  with the async checkpoint pipeline ON (``checkpoint_every=2`` chunks) vs
  OFF. The row carries both p99s, the engine-thread snapshot cost
  (device→host mirror — the ONLY checkpoint work the hot path pays), the
  background writer's commit latency, and the writer's skip/lag counters.

Hard failures (raise, so CI goes red rather than silently shipping a
regression): any retrace after warmup (every row); an engine-thread
checkpoint snapshot stalling past ``SNAPSHOT_STALL_MS``; checkpoints-on
p99 chunk latency outside noise of checkpoints-off (the async-writer
contract: durability must not ride the hot path).
"""
from __future__ import annotations

import argparse
import asyncio
import tempfile
import time

from benchmarks.common import FULL, Row, emit
from repro.serve import Gateway, parked_template

BACKENDS = ["jax-scan", "pallas-kinetic"]
CLIENT_SWEEP = [8, 32, 128] if FULL else [8, 32]
A = 256 if FULL else 32
L = 128 if FULL else 32
CHUNK = 32 if FULL else 8
SCENARIOS = ["baseline", "flash-crash", "high-vol", "thin-book"]

#: Hard ceiling on the engine-thread cost of ONE checkpoint snapshot (ms).
#: The snapshot is a device→host mirror only — if it ever approaches this,
#: serialization/fsync work has leaked back onto the engine thread.
SNAPSHOT_STALL_MS = 100.0
#: Checkpoints-on p99 must stay within this noise envelope of off.
P99_NOISE_FACTOR, P99_NOISE_FLOOR_MS = 3.0, 2.0


async def _drive(backend: str, n_clients: int, frames_per_client: int,
                 ckpt_dir=None, checkpoint_every: int = 0):
    tpl = parked_template(slots=n_clients, num_agents=A, num_levels=L,
                          num_steps=1_000_000)
    gw = Gateway(tpl, backend=backend, chunk_size=CHUNK,
                 queue_maxsize=frames_per_client + 4,
                 ckpt_dir=ckpt_dir, checkpoint_every=checkpoint_every)
    # +2 chunks: one for the lag-one pipeline, one for attach alignment
    await gw.start(chunks=frames_per_client + 2)

    t0 = time.perf_counter()
    clients = [gw.open_session(SCENARIOS[i % len(SCENARIOS)],
                               client=f"load-{i}")
               for i in range(n_clients)]
    await asyncio.gather(*(c.frames(1) for c in clients))
    attach_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    streams = await asyncio.gather(
        *(c.frames(frames_per_client - 1) for c in clients))
    stream_s = time.perf_counter() - t1
    n_frames = n_clients + sum(len(s) for s in streams)
    steps = sum(f.num_steps for s in streams for f in s)

    lat = gw.metrics.window("chunk_latency_seconds").summary()
    delta = gw.traces_delta
    out = {
        "attach_s": attach_s, "stream_s": stream_s, "frames": n_frames,
        "steps": steps, "p50_ms": lat["p50"] * 1e3,
        "p99_ms": lat["p99"] * 1e3, "traces_delta": delta,
    }
    if ckpt_dir is not None:
        health = gw.health()
        snap = gw.metrics.window("checkpoint_snapshot_seconds")
        write = gw.metrics.window("checkpoint_write_seconds")
        out["snapshot_ms_max"] = (snap.summary()["max"] * 1e3
                                  if snap is not None else 0.0)
        out["write_ms_p99"] = (write.summary()["p99"] * 1e3
                               if write is not None else 0.0)
        out["ckpt_writes"] = health["checkpoint"]["writes"]
        out["ckpt_skipped"] = health["checkpoint"]["skipped"]
        out["ckpt_pending"] = health["checkpoint"]["pending"]
    await gw.stop()
    if delta != 0:
        raise AssertionError(
            f"{backend}/c{n_clients}: {delta} retrace(s) while serving — "
            "the warm-serving contract is broken")
    return out


def run(backends=None, clients=None, frames: int = 40) -> list:
    rows: list[Row] = []
    for backend in backends or BACKENDS:
        for n in clients or CLIENT_SWEEP:
            r = asyncio.run(_drive(backend, n, frames))
            sessions_per_s = n / r["attach_s"] if r["attach_s"] else 0.0
            frames_per_s = (r["frames"] / r["stream_s"]
                            if r["stream_s"] else 0.0)
            rows.append((
                f"serve/attach/{backend}/c{n}", r["attach_s"] * 1e6,
                f"clients={n};sessions_per_s={sessions_per_s:.1f};"
                f"traces_delta={r['traces_delta']}"))
            rows.append((
                f"serve/stream/{backend}/c{n}", r["stream_s"] * 1e6,
                f"clients={n};frames_per_s={frames_per_s:.1f};"
                f"steps_per_s={r['steps'] / r['stream_s']:.0f};"
                f"chunk_p50_ms={r['p50_ms']:.3f};"
                f"chunk_p99_ms={r['p99_ms']:.3f};"
                f"traces_delta={r['traces_delta']}"))
        n = (clients or CLIENT_SWEEP)[0]
        off = asyncio.run(_drive(backend, n, frames))
        with tempfile.TemporaryDirectory() as d:
            on = asyncio.run(_drive(backend, n, frames, ckpt_dir=d,
                                    checkpoint_every=2))
        if on["snapshot_ms_max"] > SNAPSHOT_STALL_MS:
            raise AssertionError(
                f"{backend}/c{n}: engine-thread checkpoint snapshot "
                f"stalled for {on['snapshot_ms_max']:.1f}ms "
                f"(> {SNAPSHOT_STALL_MS}ms) — commit work has leaked "
                "onto the hot path")
        budget = off["p99_ms"] * P99_NOISE_FACTOR + P99_NOISE_FLOOR_MS
        if on["p99_ms"] > budget:
            raise AssertionError(
                f"{backend}/c{n}: p99 chunk latency with checkpoints on "
                f"is {on['p99_ms']:.3f}ms vs {off['p99_ms']:.3f}ms off "
                f"(budget {budget:.3f}ms) — the async writer is not "
                "keeping durability off the hot path")
        rows.append((
            f"serve/ckpt/{backend}/c{n}", on["stream_s"] * 1e6,
            f"clients={n};checkpoint_every=2;"
            f"p99_off_ms={off['p99_ms']:.3f};p99_on_ms={on['p99_ms']:.3f};"
            f"snapshot_ms_max={on['snapshot_ms_max']:.3f};"
            f"write_ms_p99={on['write_ms_p99']:.3f};"
            f"ckpt_writes={on['ckpt_writes']};"
            f"ckpt_skipped={on['ckpt_skipped']};"
            f"ckpt_pending={on['ckpt_pending']};"
            f"traces_delta={on['traces_delta']}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", nargs="*", default=BACKENDS)
    ap.add_argument("--clients", nargs="*", type=int, default=CLIENT_SWEEP)
    ap.add_argument("--frames", type=int, default=40,
                    help="frames each client consumes")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_*.json artifact here")
    ns = ap.parse_args()
    emit(run(ns.backends, ns.clients, ns.frames), json_path=ns.json,
         benchmark="serve")
