"""Streaming consumption of a live market session (SHIFT-style front door).

    PYTHONPATH=src python examples/streaming.py

A real-time consumer never wants a terminal ``SimResult`` — it wants per-step
prices as they happen. ``Session.stream`` yields one ``StepBatch(price,
volume, mid)`` per compiled chunk while the books stay device-resident, so
the consumer processes slice k while the engine's next chunk runs entirely
on-device. The demo also shows the RL stepping hook (external order
injection) and an exact snapshot/restore mid-stream.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.config import scenario_config
from repro.core.session import Engine, ExternalOrders


def main():
    cfg = scenario_config("flash-crash", num_markets=64, num_agents=128,
                          num_levels=128, num_steps=400, seed=7)
    eng = Engine("pallas-kinetic", chunk_size=100)

    print(f"streaming {cfg.num_steps} steps in chunks of 100 "
          f"(shock at step {cfg.shock_step})")
    with eng.open(cfg) as sess:
        for batch in sess.stream(cfg.num_steps):
            b = batch.to_numpy()
            lo, hi = sess.step_count - b.num_steps, sess.step_count
            print(f"  steps [{lo:3d}, {hi:3d}): "
                  f"mid={b.mid.mean():7.2f}  "
                  f"volume/market={b.volume.sum(axis=1).mean():7.1f}  "
                  f"min px={b.price.min():6.1f}")

        # RL stepping hook: snapshot, then compare a hands-off step against
        # an aggressive external buy sweep from the exact same state.
        snap = sess.snapshot()
        passive = sess.step().to_numpy()
        sess.restore(snap)
        aggressive = sess.step(ExternalOrders(
            side_buy=True, price=cfg.num_levels - 1,
            qty=np.full(cfg.num_markets, 64.0, np.float32))).to_numpy()
        print(f"next-step volume: hands-off={passive.volume.sum():8.1f}  "
              f"with external buy sweep={aggressive.volume.sum():8.1f}")
    print(f"executables traced {eng.trace_count}x "
          f"(1 chunk + 1 single-step) for the whole stream")


if __name__ == "__main__":
    main()
