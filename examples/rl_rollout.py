"""Policy-in-the-loop RL rollouts as ONE device computation (repro.env).

    PYTHONPATH=src python examples/rl_rollout.py

``Session.step`` crosses the host boundary every step — fine for probing,
fatal for RL training throughput. The pure-functional env compiles the
*entire* rollout (environment + policy + rewards + auto-reset) into a
single ``lax.scan``: one trace, one launch per rollout, zero per-step host
transfers. The demo rolls two policies over a mixed-scenario ensemble:

  * a random policy drawn from the engine's own counter RNG (stateless,
    in-graph — no host randomness anywhere), and
  * a tiny market maker quoting one lot inside the spread on alternating
    sides, earning the spread and carrying inventory.

Both share one compiled executable with the zero-action baseline (actions
ride in as runtime operands), and ``Engine.trace_count == 1`` at the end
proves no policy, scenario mixture, or reset boundary ever retraced.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.env import (InventoryPenalty, MarketFeatures, PnLReward,
                       SpreadCapture, Sum, rollout)
from repro.train.policies import make_market_maker, make_random_policy

M_PER, A, L, S = 16, 64, 64, 200

# Scripted archetypes live in repro.train.policies (shared with the test
# fixtures and the trainer's eval baseline); build once — the rollout
# executable cache keys on the function object.
random_policy = make_random_policy(L)
market_maker = make_market_maker(L)


def main():
    # A heterogeneous ensemble: every preset trains in the same rollout.
    spec = EnsembleSpec.from_scenarios(
        ["baseline", "flash-crash", "high-vol", "low-vol", "thin-book",
         "wide-book"],
        num_markets=M_PER, num_agents=A, num_levels=L, num_steps=S, seed=7)
    eng = Engine("pallas-kinetic")
    env = eng.env(spec, reward=Sum((PnLReward(), SpreadCapture(),
                                    InventoryPenalty(0.001))),
                  obs=MarketFeatures())
    print(f"env over {spec} — horizon {env.horizon}, auto-reset on")

    # The whole policy-in-the-loop rollout is ONE compiled executable.
    final, traj = rollout(env, market_maker, S)
    assert eng.trace_count == 1, eng.trace_count
    r = np.asarray(traj.reward)
    print(f"  market-maker  reward/step/market = {r.mean():+.4f}  "
          f"fills = {np.asarray(traj.fill_buy).sum() + np.asarray(traj.fill_ask).sum():7.0f}  "
          f"trace_count = {eng.trace_count}")

    # A *different scenario mixture* of the same shape reuses the warm
    # executable — scenario values ride in as device operands.
    other = eng.env(EnsembleSpec.from_scenarios(
        ["flash-crash"] * 6, num_markets=M_PER, num_agents=A, num_levels=L,
        num_steps=S, seed=7), reward=env.reward_fn, obs=env.obs_spec)
    rollout(other, market_maker, S)
    assert eng.trace_count == 1, eng.trace_count
    print(f"  all-crash mixture re-rolled with zero retraces "
          f"(trace_count = {eng.trace_count})")

    for name, policy in (("hands-off", None), ("random", random_policy)):
        final, traj = rollout(env, policy, S)
        r = np.asarray(traj.reward)
        # Pre-reset terminal inventory from the fill paths (the final
        # EnvState's portfolio is already auto-reset at the horizon).
        inv = (np.asarray(traj.fill_buy)
               - np.asarray(traj.fill_ask)).sum(axis=1)
        print(f"  {name:13s} reward/step/market = {r.mean():+.4f}  "
              f"terminal |inventory| = {np.abs(inv).mean():6.2f}")

    # Each *distinct* (policy, n_steps) rollout compiles once, ever.
    print(f"traced {eng.trace_count} executables for 4 full rollouts "
          f"({S} steps × {spec.num_markets} markets each) — "
          "zero per-step host transfers")


if __name__ == "__main__":
    main()
