"""Serve a small model with batched requests (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" not in argv:
        argv.append("--smoke")
    main(argv)
