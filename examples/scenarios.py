"""Scenario-engine tour: heterogeneous populations under named presets.

Runs every registered scenario on the persistent kernel with a four-archetype
population, prints the aggregate statistics side by side, and cross-checks
one scenario bitwise against the NumPy reference (the parity-matrix contract
in tests/test_parity_matrix.py, in miniature).

    PYTHONPATH=src python examples/scenarios.py [--backend pallas-kinetic]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.config import scenario_config, scenario_names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="pallas-kinetic")
    args = ap.parse_args()

    kw = dict(num_markets=32, num_agents=128, num_levels=128, num_steps=200,
              alpha_maker=0.15, alpha_momentum=0.15,
              alpha_fundamentalist=0.20, seed=7)

    print(f"{'scenario':>12} {'mean_px':>8} {'vol/mkt':>8} "
          f"{'trades':>7} {'volat':>7} {'kurt':>7}")
    for name in scenario_names():
        cfg = scenario_config(name, **kw)
        r = engine.simulate(cfg, backend=args.backend).to_numpy()
        print(f"{name:>12} {r.mean_clearing_price():8.2f} "
              f"{r.volume_per_market():8.0f} {r.trade_count():7.0f} "
              f"{r.volatility():7.3f} {r.excess_kurtosis():7.2f}")

    # The parity contract, in miniature: scenario configs stay bitwise
    # identical between the persistent kernel and the NumPy reference.
    cfg = scenario_config("flash-crash", **kw)
    a = engine.simulate(cfg, backend=args.backend).to_numpy()
    b = engine.simulate(cfg, backend="numpy").to_numpy()
    assert (a.price_path == b.price_path).all()
    assert (a.bid == b.bid).all() and (a.ask == b.ask).all()
    print("\nflash-crash bitwise-identical to the NumPy reference: True")


if __name__ == "__main__":
    main()
