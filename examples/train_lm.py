"""End-to-end LM training driver with fault-tolerant checkpointing.

Trains a reduced-config model for a few hundred steps on CPU; the identical
entry point drives the (16,16) production mesh on TPU (--production-mesh),
which the multi-pod dry-run validates for every assigned architecture.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-3b --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" not in argv:
        argv.append("--smoke")
    if "--steps" not in " ".join(argv):
        argv += ["--steps", "200"]
    out = main(argv)
    losses = [m["loss"] for m in out["metrics"]]
    third = max(len(losses) // 3, 1)
    assert sum(losses[-third:]) < sum(losses[:third]), "loss did not improve"
    print("loss improved over training: True")
