"""Quickstart: simulate an ensemble of call-auction markets with KineticSim.

    PYTHONPATH=src python examples/quickstart.py

Uses the Session API — the stateful open/step/close lifecycle:

    eng  = Engine(backend)     # caches compiled executables
    sess = eng.open(cfg)       # live device-resident MarketState
    sess.run(n)                # advance n steps, get a StepBatch

Migration note: the one-shot ``engine.simulate(cfg, backend=...)`` is kept
as a thin compatibility wrapper over a one-session run — existing code
keeps working unchanged, but a warm session amortizes compilation across
calls and never re-initializes state.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.config import MarketConfig
from repro.core.session import Engine


def main():
    cfg = MarketConfig(num_markets=64, num_agents=128, num_levels=128,
                       num_steps=100, seed=42)
    # The paper's engine: persistent, VMEM-resident clearing kernel
    # (interpret mode on CPU; Mosaic lowering on TPU).
    eng = Engine("pallas-kinetic")
    with eng.open(cfg) as sess:
        result = sess.run_to_result().to_numpy()
    print(f"simulated {cfg.num_markets} markets x {cfg.num_steps} steps "
          f"x {cfg.num_agents} agents = {cfg.events():,} agent-events")
    print(f"mean clearing price : {result.mean_clearing_price():8.3f}")
    print(f"volume per market   : {result.volume_per_market():8.1f}")
    print(f"trades per market   : {result.trade_count():8.1f}")
    print(f"return volatility   : {result.volatility():8.3f}")

    # A second session reuses the cached executable: zero retraces.
    with eng.open(cfg) as sess:
        sess.run(cfg.num_steps)
    print(f"compiled executables traced {eng.trace_count}x for 2 sessions")

    # Cross-check against the NumPy reference — bitwise identical (paper
    # IV-B); the compat wrapper is itself a one-session run.
    ref = engine.simulate(cfg, backend="numpy").to_numpy()
    assert (ref.price_path == result.price_path).all()
    print("bitwise-identical to the NumPy reference: True")


if __name__ == "__main__":
    main()
