"""Minimal async serving client: N sessions over one warm gateway.

    PYTHONPATH=src python examples/serve_client.py [n_clients]

Starts an in-process :class:`repro.serve.Gateway` (one warm engine, all
slots parked), opens ``n_clients`` concurrent sessions with a mixture of
scenario presets, and consumes each session's frame stream — the same
code path a WebSocket consumer runs, minus the socket. Also probes the
HTTP health endpoint the load balancer would use. Attaching a session is
a parameter-row splice into the running ensemble, so the whole demo
compiles exactly once, during ``Gateway.start``; the final line asserts
``traces_delta == 0``.
"""
import asyncio
import json
import os
import sys
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve import Gateway, parked_template
from repro.serve.transport import HealthServer

SCENARIOS = ["baseline", "flash-crash", "high-vol", "thin-book"]


async def consume(name: str, cs, n_frames: int) -> None:
    """One client: read frames as they stream, print a rolling summary."""
    got = 0
    async for frame in cs.subscription:
        if not hasattr(frame, "mid"):       # control Event (attach/close)
            print(f"  {name}: event {frame.kind} {frame.payload}")
            if frame.kind == "closed":
                return
            continue
        print(f"  {name}: chunk {frame.seq:2d} steps "
              f"[{frame.step0}, {frame.step0 + frame.num_steps}) "
              f"mid={float(frame.mid.mean()):6.2f}")
        got += 1
        if got >= n_frames:
            cs.close()
            return


async def main(n_clients: int) -> None:
    template = parked_template(slots=max(8, n_clients), num_agents=64,
                               num_levels=64, num_steps=100_000)
    gateway = Gateway(template, backend="jax-scan", chunk_size=32,
                      queue_maxsize=8)
    await gateway.start()

    health = HealthServer(gateway)
    port = await health.start()

    def probe():   # blocking client -> executor, off the serving loop
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/healthz", timeout=5) as r:
            return json.loads(r.read())

    loop = asyncio.get_running_loop()
    print(f"healthz: {await loop.run_in_executor(None, probe)}")

    clients = [gateway.open_session(SCENARIOS[i % len(SCENARIOS)],
                                    client=f"user-{i}")
               for i in range(n_clients)]
    print(f"{n_clients} sessions attached to "
          f"{gateway.health()['slots']} slots\n")
    await asyncio.gather(*(consume(cs.client, cs, n_frames=4)
                           for cs in clients))

    await health.stop()
    await gateway.stop()
    assert gateway.traces_delta == 0
    print(f"\nserved {n_clients} clients with "
          f"{gateway.traces_delta} retraces after warmup")


if __name__ == "__main__":
    asyncio.run(main(int(sys.argv[1]) if len(sys.argv) > 1 else 6))
