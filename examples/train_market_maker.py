"""Train a market-maker on device, end to end (repro.train).

    PYTHONPATH=src python examples/train_market_maker.py

The flagship RL workload: a learned market-maker (small actor-critic MLP
over a discrete quote grid) trained with PPO against a flash-crash +
high-vol scenario mixture, rewarded for spread capture and penalized for
inventory. The entire update — rollout collection, GAE, every minibatched
gradient step — compiles into ONE jitted executable: a training span of
U updates makes zero per-step and zero per-update host transfers, which
is the engine's device-residency thesis extended to the gradient step.

The run demonstrates the full lifecycle:

  1. train in warm spans (``Engine.trace_count`` stays flat after the
     first call — U more updates never retrace);
  2. checkpoint the trainer state (policy + Adam moments + PRNG key +
     env states) through the crash-consistent ``CheckpointManager``,
     restore it, and bitwise-continue the learning curve;
  3. evaluate the learned policy greedily against the scripted maker
     archetype on a held-out mixture it never trained on.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.params import EnsembleSpec
from repro.core.session import Engine
from repro.env import (InventoryPenalty, MarketFeatures, SpreadCapture, Sum,
                       rollout)
from repro.train import (PPOConfig, PPOTrainer, fit, make_market_maker,
                         restore_train_checkpoint, save_train_checkpoint)

M_PER, A, L, T = 4, 32, 32, 32


def mixture(scenarios):
    return EnsembleSpec.from_scenarios(
        scenarios, num_markets=M_PER, num_agents=A, num_levels=L,
        num_steps=T, seed=11)


def main():
    eng = Engine("jax-scan")
    env = eng.env(mixture(["flash-crash", "high-vol"]),
                  reward=Sum((SpreadCapture(), InventoryPenalty(0.001))),
                  obs=MarketFeatures())
    cfg = PPOConfig(rollout_len=T, num_updates=8, num_envs=4,
                    num_epochs=2, num_minibatches=8, lr=1e-3,
                    ent_coef=0.003, seed=0)
    trainer = PPOTrainer(env, cfg)
    print(f"PPO over {env.spec}: {cfg.num_envs} seed-envs × "
          f"{env.spec.num_markets} markets × {T} steps per update")

    # --- 1. warm spans: one executable, zero retraces after the first ---
    ts = trainer.init()
    ts, _ = trainer.train(ts, 8)
    warm = eng.trace_count
    out = fit(trainer, ts, total_updates=16, updates_per_call=8)
    ts = out["ts"]
    r = out["history"]["reward"]
    assert eng.trace_count == warm, eng.trace_count
    print(f"  24 updates in 3 jitted spans — trace_count still {warm}, "
          f"{out['env_steps_per_s']:,.0f} env-steps/s while training")
    print(f"  reward/step/market: {r[0]:+.4f} (first) -> "
          f"{r[-1]:+.4f} (last)")

    # --- 2. checkpoint / restore: bitwise continuation ---
    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, async_write=False)
        save_train_checkpoint(mgr, trainer, ts)
        restored = restore_train_checkpoint(mgr, trainer)
        ts_a, m_a = trainer.train(ts, 4)
        ts_b, m_b = trainer.train(restored, 4)
        assert np.array_equal(np.asarray(m_a["reward"]),
                              np.asarray(m_b["reward"]))
    ts = ts_a
    print("  checkpoint -> restore -> 4 more updates: learning curve "
          "continues bitwise")

    # --- 3. held-out eval vs the scripted maker archetype ---
    held = eng.env(mixture(["flash-crash", "baseline"]),
                   reward=SpreadCapture(), obs=MarketFeatures())
    learned = float(np.asarray(
        trainer.evaluate(ts.params, env=held, n_steps=T).reward).mean())
    _, sb = rollout(held, make_market_maker(L), T)
    scripted = float(np.asarray(sb.reward).mean())
    verdict = "beats" if learned > scripted else "does not beat"
    print(f"  held-out spread capture: learned {learned:+.4f} {verdict} "
          f"scripted {scripted:+.4f}")


if __name__ == "__main__":
    main()
