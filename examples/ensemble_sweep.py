"""Paper §IV-J: market-composition parameter sweep with stylized facts.

Sweeps the momentum-agent fraction and reports volatility escalation,
fat tails, volume stimulation, and volatility clustering — the experiment
the paper calls "trivial with KineticSim, hours-to-days on CPU simulators".

    PYTHONPATH=src python examples/ensemble_sweep.py [--full]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engine
from repro.core.config import MarketConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep (0.00..0.70 step 0.05, S=1000)")
    ap.add_argument("--backend", default="jax-scan")
    args = ap.parse_args()
    fracs = ([i * 0.05 for i in range(15)] if args.full
             else [0.0, 0.2, 0.4, 0.6])
    steps = 1000 if args.full else 300

    print(f"{'a_mom':>6} {'volatility':>11} {'ex_kurt':>8} {'volume':>8}")
    t0 = time.time()
    events = 0
    for amom in fracs:
        cfg = MarketConfig(num_markets=64, num_agents=256, num_steps=steps,
                           alpha_maker=0.15, alpha_momentum=round(amom, 2),
                           noise_delta=2.0, p_marketable=0.2, seed=1)
        r = engine.simulate(cfg, backend=args.backend).to_numpy()
        events += cfg.events()
        print(f"{amom:6.2f} {r.volatility():11.3f} "
              f"{r.excess_kurtosis():8.2f} "
              f"{float(r.volume_path.mean()):8.1f}")
    dt = time.time() - t0
    cfg = MarketConfig(num_markets=64, num_agents=256, num_steps=steps,
                       alpha_momentum=0.40, noise_delta=2.0,
                       p_marketable=0.2, seed=1)
    r = engine.simulate(cfg, backend=args.backend).to_numpy()
    acf_r = r.autocorrelation(20, absolute=False)
    acf_a = r.autocorrelation(20, absolute=True)
    print(f"\nACF(r,1)={acf_r[1]:+.3f} (bid-ask bounce) "
          f"ACF(|r|,1)={acf_a[1]:+.3f} ACF(|r|,10)={acf_a[10]:+.3f} "
          f"(volatility clustering)")
    print(f"{events:,} agent-events in {dt:.2f}s "
          f"({events / dt:.3g} events/s)")


if __name__ == "__main__":
    main()
